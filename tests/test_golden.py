"""Golden-schedule regression harness (ISSUE 4).

A small frozen trace (``tests/golden/trace.json`` — committed, so no
dependency on numpy RNG stream stability) is scheduled by every policy
across clean / heterogeneous / faulted / degraded scenarios, and the
resulting schedules are compared byte-for-byte against committed
fixtures (``tests/golden/expected.json``): exact ``total_flow`` float,
peak queue depth, migration count, and a sha256 over every per-job
record.  Any schedule drift — a reordered tiebreak, a changed float
chain, a cache answering with a different placement — fails here without
rerunning the full property suites, making the PR-3 "bit-identical"
guarantee cheaply enforceable by future perf refactors.

The matrix deliberately sticks to ``refine_mapping=False`` engines: the
refine pipeline's swap deltas run through BLAS dgemm (``ind @ W``),
whose results are build-dependent, so refine equivalence is held by the
same-process property suites (tests/test_vectorized.py,
tests/test_sched_cache.py) instead of cross-machine fixtures.  Every op
in the greedy + alpha_matrix + simulator path is elementwise IEEE or
integer, identical across platforms.

Regenerate after a *deliberate* schedule change:

    PYTHONPATH=src python tests/test_golden.py --regen

and commit both fixture files with the PR that changed the schedule.
"""
import json
import pathlib

import pytest

pytestmark = pytest.mark.sched

from repro.core import (  # noqa: E402
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    Degradation,
    Scenario,
    ServerClass,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
)
from repro.core.scenario import jobs_from_dicts, jobs_to_dicts  # noqa: E402

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"
TRACE_PATH = GOLDEN_DIR / "trace.json"
EXPECTED_PATH = GOLDEN_DIR / "expected.json"
SCENARIO_PATH = GOLDEN_DIR / "scenario_straggler.json"
# the committed Scenario fixture replays this expected.json entry
SCENARIO_OF = "A-SRPT (migrate) @het+straggler"

# Frozen trace recipe — only used by --regen; the committed trace.json is
# what tests consume, so numpy RNG stream changes cannot shift fixtures.
TRACE_CFG = TraceConfig(
    n_jobs=240,
    horizon=2400.0,
    seed=11,
    single_gpu_frac=0.4,
    max_gpus_per_job=16,
)

def _hom_cluster() -> ClusterSpec:
    return ClusterSpec(
        num_servers=8, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )


def _het_cluster() -> ClusterSpec:
    return ClusterSpec.heterogeneous(
        [
            ServerClass(count=3, gpus_per_server=8, b_inter=12.5e9, name="a"),
            ServerClass(count=3, gpus_per_server=8, b_inter=1.25e9, name="b"),
            ServerClass(
                count=3, gpus_per_server=4, b_inter=1.25e9, b_intra=50e9,
                name="c",
            ),
        ],
        b_intra=300e9,
    )


_FAULTS = [(600.0, 0), (650.0, 1)]
# deep slowdowns on two gen-a servers + one gen-b: chosen so the frozen
# trace actually migrates (pinning the checkpoint-restart path), which
# needs long-enough jobs caught on a badly-slowed server
_STRAGGLERS = [(400.0, 0, 0.1), (400.0, 1, 0.1), (700.0, 4, 0.2)]


def _mean(**kw):
    return ASRPTPolicy(make_predictor("mean"), tau=2.0, **kw)


# name -> (cluster factory, policy factory, simulate kwargs); every engine
# here is matmul-free (see module docstring)
SCENARIOS = {
    "A-SRPT @hom": (_hom_cluster, _mean, {}),
    "A-SRPT (uncached) @hom": (
        _hom_cluster, lambda: _mean(placement_cache=False), {}
    ),
    "SPJF @hom": (
        _hom_cluster, lambda: BASELINES["SPJF"](make_predictor("mean")), {}
    ),
    "SPWF @hom": (
        _hom_cluster, lambda: BASELINES["SPWF"](make_predictor("mean")), {}
    ),
    "WCS-Duration @hom": (
        _hom_cluster,
        lambda: BASELINES["WCS-Duration"](make_predictor("mean")), {},
    ),
    "WCS-Workload @hom": (
        _hom_cluster,
        lambda: BASELINES["WCS-Workload"](make_predictor("mean")), {},
    ),
    "WCS-SubTime @hom": (
        _hom_cluster,
        lambda: BASELINES["WCS-SubTime"](make_predictor("mean")), {},
    ),
    "A-SRPT @het": (_het_cluster, _mean, {}),
    "A-SRPT @het+fault": (_het_cluster, _mean, {"faults": _FAULTS}),
    "A-SRPT (migrate) @het+straggler": (
        _het_cluster,
        lambda: _mean(migrate=True, migration_penalty=20.0),
        {"degradations": _STRAGGLERS},
    ),
}


def dump_jobs(jobs) -> list:
    return jobs_to_dicts(jobs)


def load_jobs() -> list:
    # the frozen trace is a documented instance of the Scenario jobs
    # array (repro.core.scenario); loading through the one shared loader
    # keeps the schema honest
    return jobs_from_dicts(json.loads(TRACE_PATH.read_text()))


def schedule_digest(result) -> str:
    return result.schedule_digest()


def straggler_scenario_fixture(jobs) -> Scenario:
    """The straggler golden case as a first-class Scenario (committed at
    ``tests/golden/scenario_straggler.json``; CI replays it through
    ``sched_scale --scenario``)."""
    return Scenario(
        jobs=tuple(jobs),
        cluster=_het_cluster(),
        events=tuple(Degradation(t, m, factor=f) for t, m, f in _STRAGGLERS),
        name="golden-straggler",
    )


def run_scenario(name: str, jobs):
    cluster_fn, policy_fn, kwargs = SCENARIOS[name]
    res = simulate(jobs, cluster_fn(), policy_fn(), **kwargs)
    return {
        "total_flow": res.total_flow_time,
        "peak_depth": res.peak_queue_depth,
        "n_migrations": res.n_migrations,
        "sha256": schedule_digest(res),
    }


@pytest.fixture(scope="module")
def golden_jobs():
    return load_jobs()


@pytest.fixture(scope="module")
def expected():
    return json.loads(EXPECTED_PATH.read_text())


def test_fixtures_cover_every_scenario(expected):
    assert set(expected) == set(SCENARIOS)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_schedule(name, golden_jobs, expected):
    got = run_scenario(name, golden_jobs)
    want = expected[name]
    assert got["sha256"] == want["sha256"], (
        f"schedule drift in {name!r}: flow {got['total_flow']!r} vs "
        f"golden {want['total_flow']!r}, peak depth {got['peak_depth']} "
        f"vs {want['peak_depth']} — if the change is deliberate, "
        f"regenerate with `PYTHONPATH=src python tests/test_golden.py "
        f"--regen` and commit the fixtures"
    )
    assert got["total_flow"] == want["total_flow"], name
    assert got["peak_depth"] == want["peak_depth"], name
    assert got["n_migrations"] == want["n_migrations"], name


def test_scenario_fixture_replays_straggler_golden(golden_jobs, expected):
    """The committed Scenario file (jobs + cluster + events in one JSON)
    loads through the schema and replays the straggler golden schedule
    byte for byte — the serialization layer cannot drift from the
    engine."""
    sc = Scenario.load(SCENARIO_PATH)
    assert sc == straggler_scenario_fixture(golden_jobs)
    res = simulate(sc, _mean(migrate=True, migration_penalty=20.0))
    assert res.schedule_digest() == expected[SCENARIO_OF]["sha256"]
    assert res.total_flow_time == expected[SCENARIO_OF]["total_flow"]


def test_frozen_trace_matches_recipe_stats():
    """Sanity on the committed trace itself (not the RNG): job count and
    GPU-demand clamp of the recipe hold."""
    jobs = load_jobs()
    assert len(jobs) == TRACE_CFG.n_jobs
    assert max(j.g for j in jobs) <= TRACE_CFG.max_gpus_per_job
    assert all(
        jobs[i].arrival <= jobs[i + 1].arrival for i in range(len(jobs) - 1)
    )


def _regen() -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    jobs = generate_trace(TRACE_CFG)
    TRACE_PATH.write_text(json.dumps(dump_jobs(jobs)) + "\n")
    jobs = load_jobs()  # fixtures must reflect the round-tripped trace
    expected = {name: run_scenario(name, jobs) for name in SCENARIOS}
    EXPECTED_PATH.write_text(json.dumps(expected, indent=2) + "\n")
    straggler_scenario_fixture(jobs).dump(SCENARIO_PATH)
    for name, row in expected.items():
        print(f"{name}: flow={row['total_flow']!r} "
              f"depth={row['peak_depth']} migs={row['n_migrations']}")
    print(f"wrote {TRACE_PATH} and {EXPECTED_PATH}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
