"""detlint test suite (ISSUE 10).

Covers: the fixture-file matrix (one positive + negative snippet per
rule), suppression parsing (missing reason fails), structured-allowlist
behavior, JSON/github output formats, CLI exit codes, config parsing
(tomllib vs the 3.10 mini-parser), and the repo gate itself —
``src/repro/core`` must lint clean with every suppression carrying a
reason.
"""

import json
import re
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "detlint"
PYPROJECT = REPO / "pyproject.toml"

sys.path.insert(0, str(REPO / "src"))

from repro.analysis.detlint import (  # noqa: E402
    DET900,
    AllowEntry,
    Config,
    UsageError,
    _parse_detlint_toml,
    all_rules,
    config_from_dict,
    lint_paths,
    load_config,
    main,
)


def run_fixture(name, config=None, **kw):
    cfg = config or Config(root=FIXTURES)
    return lint_paths([str(FIXTURES / name)], config=cfg, **kw)


def rules_hit(report, unsuppressed_only=True):
    src = report.unsuppressed if unsuppressed_only else report.findings
    return sorted({f.rule for f in src})


# ---------------------------------------------------------------------------
# Fixture matrix: one positive + one negative file per rule
# ---------------------------------------------------------------------------

MATRIX = [
    # (bad fixture, rule, expected finding count)
    ("det001_bad.py", "DET001", 4),  # for-loop, listcomp, list(), float sum
    ("det002_bad.py", "DET002", 3),  # random.*, np.random.<fn>, bare rng
    ("det003_bad.py", "DET003", 2),  # aliased perf_counter, datetime.now
    ("det004_bad.py", "DET004", 3),  # listdir, glob, iterdir
    ("det005_bad.py", "DET005", 2),  # += float, sum()
    ("det006_bad.py", "DET006", 2),  # key=id, dict[id(x)]
    ("det007_bad.py", "DET007", 1),  # undocumented popitem
    ("pol001_bad.py", "POL001", 2),  # shadowed dual override + legacy
    ("pol002_bad.py", "POL002", 1),  # frozen mutation outside post_init
]


@pytest.mark.parametrize("fixture,rule,count", MATRIX)
def test_positive_fixture(fixture, rule, count):
    report = run_fixture(fixture, select=[rule])
    found = [f for f in report.unsuppressed if f.rule == rule]
    lines = [(f.line, f.message) for f in found]
    assert len(found) == count, f"{fixture}: {lines}"
    assert all(f.path.endswith(fixture) for f in found)
    assert all(f.line > 0 and f.hint for f in found)


@pytest.mark.parametrize(
    "fixture,rule",
    [(bad.replace("_bad", "_ok"), rule) for bad, rule, _ in MATRIX],
)
def test_negative_fixture(fixture, rule):
    report = run_fixture(fixture, select=[rule])
    assert report.unsuppressed == [], [
        (f.rule, f.line, f.message) for f in report.unsuppressed
    ]


def test_negative_fixtures_clean_under_all_rules():
    # the _ok files must be clean under the *full* rule set, not just
    # the rule they mirror (det007_ok's skip comment, pol002_ok's
    # post_init, ... must not trip a sibling rule)
    for bad, _rule, _n in MATRIX:
        name = bad.replace("_bad", "_ok")
        report = run_fixture(name)
        assert report.unsuppressed == [], (
            name,
            [(f.rule, f.line, f.message) for f in report.unsuppressed],
        )


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------


def test_suppression_with_reason_silences_finding():
    report = run_fixture("suppress_ok.py")
    assert report.unsuppressed == [], [
        (f.rule, f.line) for f in report.unsuppressed
    ]
    sup = [f for f in report.findings if f.suppressed]
    assert len(sup) == 2  # preceding-comment form + same-line form
    assert all(f.suppression == "inline" and f.reason for f in sup)


def test_suppression_missing_reason_fails():
    report = run_fixture("suppress_missing_reason.py")
    det900 = [f for f in report.unsuppressed if f.rule == DET900]
    det003 = [f for f in report.unsuppressed if f.rule == "DET003"]
    assert len(det900) == 2  # bare skip= and empty parens, both malformed
    assert len(det003) == 2  # and the findings stay unsuppressed
    assert all("reason" in f.message for f in det900)


def test_suppression_for_wrong_rule_does_not_silence(tmp_path):
    src = (
        "import time\n"
        "def f():\n"
        "    # detlint: skip=DET001(wrong rule id)\n"
        "    return time.time()\n"
    )
    p = tmp_path / "wrong_rule.py"
    p.write_text(src)
    report = lint_paths([str(p)], config=Config(root=tmp_path))
    assert rules_hit(report) == ["DET003"]


def test_directive_in_docstring_is_not_parsed(tmp_path):
    p = tmp_path / "docstring.py"
    p.write_text('"""Docs show `# detlint: skip=DET001` examples."""\n')
    report = lint_paths([str(p)], config=Config(root=tmp_path))
    assert report.findings == []


# ---------------------------------------------------------------------------
# Structured allowlist
# ---------------------------------------------------------------------------


def _allow_config(**kw):
    entry = dict(
        rule="DET003", path="det003_bad.py", reason="test allow", context=""
    )
    entry.update(kw)
    return Config(root=FIXTURES, allow=[AllowEntry(**entry)])


def test_allowlist_suppresses_matching_findings():
    report = run_fixture("det003_bad.py", config=_allow_config())
    assert report.unsuppressed == []
    assert all(
        f.suppression == "allowlist" and f.reason == "test allow"
        for f in report.findings
    )


def test_allowlist_context_scopes_the_entry():
    # context="stamp" allows only the perf_counter inside stamp();
    # datetime.now() inside label() must still fail
    report = run_fixture("det003_bad.py", config=_allow_config(context="stamp"))
    assert [f.qualname for f in report.unsuppressed] == ["label"]
    assert [f.qualname for f in report.findings if f.suppressed] == ["stamp"]


def test_allowlist_path_glob_must_match():
    report = run_fixture(
        "det003_bad.py", config=_allow_config(path="other/*.py")
    )
    assert len(report.unsuppressed) == 2


def test_allow_entry_requires_reason():
    data = {
        "tool": {
            "detlint": {
                "allow": [{"rule": "DET003", "path": "x.py", "reason": " "}]
            }
        }
    }
    with pytest.raises(UsageError, match="reason is mandatory"):
        config_from_dict(data, root=REPO)


def test_unknown_config_key_fails_loudly():
    with pytest.raises(UsageError, match="unknown .* key"):
        config_from_dict({"tool": {"detlint": {"path": []}}}, root=REPO)
    with pytest.raises(UsageError, match="unknown rule"):
        config_from_dict(
            {"tool": {"detlint": {"ignore": ["DET999"]}}}, root=REPO
        )


# ---------------------------------------------------------------------------
# Config parsing: tomllib and the 3.10 mini-parser agree on the repo file
# ---------------------------------------------------------------------------


def test_mini_parser_reads_repo_pyproject():
    data = _parse_detlint_toml(PYPROJECT.read_text(encoding="utf-8"))
    cfg = config_from_dict(data, root=REPO)
    assert cfg.paths == ["src/repro/core", "src/repro/analysis"]
    assert [e.rule for e in cfg.allow] == ["DET003", "DET003"]
    assert all(e.reason for e in cfg.allow)
    assert cfg.per_rule_exclude["DET002"] == ["tests/*", "benchmarks/*"]
    assert any("SimResult" in s for s in cfg.digest_scopes)


def test_mini_parser_matches_tomllib_when_available():
    tomllib = pytest.importorskip("tomllib")
    with PYPROJECT.open("rb") as fh:
        full = tomllib.load(fh)
    mini = _parse_detlint_toml(PYPROJECT.read_text(encoding="utf-8"))
    assert mini["tool"]["detlint"] == full["tool"]["detlint"]


def test_mini_parser_rejects_unsupported_values():
    with pytest.raises(UsageError, match="unsupported TOML value"):
        _parse_detlint_toml("[tool.detlint]\npaths = { a = 1 }\n")


def test_mini_parser_rejects_non_string_array_elements():
    # a malformed array must fail loudly, not silently parse to []
    with pytest.raises(UsageError, match="array element"):
        _parse_detlint_toml("[tool.detlint]\npaths = [1, 2]\n")
    with pytest.raises(UsageError, match="array element"):
        _parse_detlint_toml('[tool.detlint]\npaths = ["a", true]\n')


def test_mini_parser_array_commas_and_trailing_comma():
    data = _parse_detlint_toml(
        '[tool.detlint]\npaths = ["a,b", "c", ]\nempty = []\n'
    )
    det = data["tool"]["detlint"]
    assert det["paths"] == ["a,b", "c"]
    assert det["empty"] == []


# ---------------------------------------------------------------------------
# Rule registry / engine plumbing
# ---------------------------------------------------------------------------


def test_registry_has_all_documented_rules():
    ids = set(all_rules())
    assert {
        "DET001", "DET002", "DET003", "DET004", "DET005", "DET006",
        "DET007", "POL001", "POL002",
    } <= ids


def test_select_and_ignore_scope_the_run():
    only = run_fixture("det002_bad.py", select=["DET002"])
    assert rules_hit(only) == ["DET002"]
    none = run_fixture("det002_bad.py", ignore=["DET002"])
    assert "DET002" not in rules_hit(none)


def test_per_rule_exclude_skips_files():
    cfg = Config(root=FIXTURES, per_rule_exclude={"DET003": ["det003_*"]})
    report = run_fixture("det003_bad.py", config=cfg)
    assert "DET003" not in rules_hit(report)


def test_det001_sum_over_set_cleared_only_for_int_like(tmp_path):
    # sum() is order-insensitive only for exact (int-like) elements:
    # float summation rounds per add, so set order leaks into it
    p = tmp_path / "m.py"
    p.write_text(
        "s = {1.5, 2.5}\n"
        "total = sum(x for x in s)\n"       # flagged: float-valued
        "n = sum(1 for _ in s)\n"           # cleared: counter
        "k = sum(len(str(x)) for x in s)\n"  # cleared: len() is exact
    )
    report = lint_paths([str(p)], config=Config(root=tmp_path))
    hits = [f for f in report.unsuppressed if f.rule == "DET001"]
    assert [f.line for f in hits] == [2], [
        (f.line, f.message) for f in hits
    ]


def test_det005_config_scope_without_marker(tmp_path):
    p = tmp_path / "agg.py"
    p.write_text(
        "class Agg:\n"
        "    def add(self, x):\n"
        "        self.total += x\n"
    )
    scoped = Config(root=tmp_path, digest_scopes=["agg.py::Agg"])
    assert rules_hit(lint_paths([str(p)], config=scoped)) == ["DET005"]
    unscoped = Config(root=tmp_path)
    assert rules_hit(lint_paths([str(p)], config=unscoped)) == []


# ---------------------------------------------------------------------------
# CLI: exit codes + output formats (in-process main(), plus one true
# subprocess run proving the `python -m` entry point CI uses)
# ---------------------------------------------------------------------------


def cli(*argv):
    return main(list(argv))


def test_cli_exit_codes(capsys):
    bad = str(FIXTURES / "det001_bad.py")
    ok = str(FIXTURES / "det001_ok.py")
    assert cli(bad, "--no-config") == 1
    assert cli(ok, "--no-config") == 0
    assert cli("no/such/path.py", "--no-config") == 2
    assert cli(bad, "--no-config", "--select", "NOPE01") == 2
    assert cli("--no-config") == 2  # no paths anywhere
    capsys.readouterr()


def test_cli_json_format(capsys):
    rc = cli(str(FIXTURES / "det006_bad.py"), "--no-config", "--format=json")
    out = capsys.readouterr().out
    doc = json.loads(out)
    assert rc == 1
    assert doc["version"] == 1 and doc["n_files"] == 1
    assert doc["counts"]["unsuppressed"] == len(doc["findings"]) > 0
    f = doc["findings"][0]
    assert {"rule", "path", "line", "col", "message", "hint"} <= set(f)


def test_cli_github_format(capsys):
    rc = cli(str(FIXTURES / "det004_bad.py"), "--no-config", "--format=github")
    out = capsys.readouterr().out
    assert rc == 1
    ann = [ln for ln in out.splitlines() if ln.startswith("::error ")]
    assert len(ann) == 3
    assert all(
        re.match(r"::error file=.+,line=\d+,col=\d+,title=detlint DET004::", a)
        for a in ann
    )


def test_cli_github_columns_are_one_based(capsys):
    # GitHub annotations are 1-based; Finding.col is a 0-based ast
    # col_offset, so every annotation must shift by one
    bad = str(FIXTURES / "det001_bad.py")
    report = lint_paths([bad], config=Config(root=FIXTURES))
    cols0 = [f.col for f in report.unsuppressed]
    assert cols0, "fixture produced no findings"
    rc = cli(bad, "--no-config", "--format=github")
    out = capsys.readouterr().out
    ann = [int(m.group(1)) for m in re.finditer(r",col=(\d+),", out)]
    assert rc == 1
    assert sorted(ann) == sorted(c + 1 for c in cols0)
    assert min(ann) >= 1


def test_cli_list_rules(capsys):
    assert cli("--list-rules") == 0
    out = capsys.readouterr().out
    assert "DET001" in out and "POL002" in out and "DET900" in out


def test_cli_module_entry_point_fails_on_seeded_violation():
    # what the CI detlint job runs, pointed at a violation on purpose:
    # the gate must demonstrably fail (exit 1, an annotation emitted)
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.analysis.detlint",
            str(FIXTURES / "det002_bad.py"), "--no-config",
            "--format=github",
        ],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1, proc.stderr
    assert "::error " in proc.stdout and "DET002" in proc.stdout


def _run_module_cli(*argv):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.detlint", *argv],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )


def test_cli_module_entry_point_enforces_policy_rules():
    # regression: under `python -m` the module runs as __main__;
    # all_rules()'s `from . import policy_rules` must register the POL
    # rules into *this* registry, not a second canonical-name copy —
    # otherwise the exact command CI runs silently skips POL001/POL002
    listing = _run_module_cli("--list-rules")
    assert listing.returncode == 0, listing.stderr
    assert "POL001" in listing.stdout and "POL002" in listing.stdout

    proc = _run_module_cli(
        str(FIXTURES / "pol001_bad.py"),
        str(FIXTURES / "pol002_bad.py"),
        "--no-config",
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "POL001" in proc.stdout and "POL002" in proc.stdout


# ---------------------------------------------------------------------------
# The repo gate: the acceptance criterion, as a test
# ---------------------------------------------------------------------------


def test_repo_lints_clean_with_configured_gate(capsys):
    rc = cli(
        "src/repro/core", "src/repro/analysis",
        "--config", str(PYPROJECT),
    )
    capsys.readouterr()
    assert rc == 0


def test_repo_suppressions_all_carry_reasons():
    cfg = load_config(PYPROJECT)
    report = lint_paths(["src/repro/core", "src/repro/analysis"], config=cfg)
    assert report.unsuppressed == [], [
        (f.path, f.line, f.rule) for f in report.unsuppressed
    ]
    suppressed = [f for f in report.findings if f.suppressed]
    # the known sanctioned sites: 8 wall_s perf_counter reads + the
    # heavy_edge LRU eviction
    assert len(suppressed) == 9
    assert all(f.reason.strip() for f in suppressed)
    by_rule = {}
    for f in suppressed:
        by_rule.setdefault(f.rule, []).append(f)
    assert len(by_rule["DET003"]) == 8
    assert len(by_rule["DET007"]) == 1
    assert by_rule["DET007"][0].path == "src/repro/core/heavy_edge.py"
