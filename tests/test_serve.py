"""Serving: prefill+decode equivalence, SWA ring buffer, ServeEngine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L
from repro.configs import reduced_config
from repro.models import Model
from repro.serve.engine import Request, ServeEngine


def _full_logits(model, params, toks):
    cfg = model.cfg
    h, _ = model._embed_inputs(params, {"tokens": toks})
    qp = jnp.arange(toks.shape[1], dtype=jnp.int32)
    h, _, _ = model._backbone(params, h, qp)
    h = L.rms_norm(h, params["final_norm"])
    return L.unembed(params["embed"], cfg, h)


@pytest.mark.parametrize(
    "arch", ["deepseek-7b", "granite-34b", "mamba2-370m",
             "jamba-1.5-large-398b"]
)
def test_prefill_decode_equals_forward(arch):
    cfg = reduced_config(arch, capacity_factor=16.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, P, D = 2, 24, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P + D)), jnp.int32)
    full = _full_logits(model, params, toks)
    cache = model.init_cache(B, P + D, dtype=jnp.float32)
    lg, cache = model.prefill(params, {"tokens": toks[:, :P]}, cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, P - 1]), atol=2e-4, rtol=2e-4
    )
    for i in range(D):
        lg, cache = model.decode_step(
            params, cache, toks[:, P + i : P + i + 1],
            jnp.asarray(P + i, jnp.int32),
        )
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, P + i]),
            atol=2e-4, rtol=2e-4,
        )


def test_swa_ring_buffer_matches_full_when_window_covers():
    """With window >= context, SWA decode == full-attention decode."""
    cfg_swa = reduced_config("h2o-danube-3-4b", sliding_window=64)
    cfg_full = reduced_config("h2o-danube-3-4b", sliding_window=None)
    m_swa, m_full = Model(cfg_swa), Model(cfg_full)
    params = m_swa.init(jax.random.PRNGKey(0))  # same tree for both
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg_swa.vocab_size, (1, 40)), jnp.int32)
    c_swa = m_swa.init_cache(1, 64, dtype=jnp.float32)
    c_full = m_full.init_cache(1, 64, dtype=jnp.float32)
    l1, c_swa = m_swa.prefill(params, {"tokens": toks[:, :32]}, c_swa)
    l2, c_full = m_full.prefill(params, {"tokens": toks[:, :32]}, c_full)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4, rtol=2e-4)
    for i in range(4):
        l1, c_swa = m_swa.decode_step(params, c_swa, toks[:, 32+i:33+i],
                                      jnp.asarray(32+i, jnp.int32))
        l2, c_full = m_full.decode_step(params, c_full, toks[:, 32+i:33+i],
                                        jnp.asarray(32+i, jnp.int32))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   atol=3e-4, rtol=3e-4)


def test_swa_ring_wraps():
    """Decode past the window: ring slots are overwritten, old tokens
    leave the attention span, and logits stay finite."""
    cfg = reduced_config("h2o-danube-3-4b", sliding_window=16)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)), jnp.int32)
    cache = model.init_cache(1, 64, dtype=jnp.float32)  # ring length 16
    assert cache["sub0"]["k"].shape[2] == 16
    lg, cache = model.prefill(params, {"tokens": toks[:, :32]}, cache)
    for i in range(20):  # wraps the 16-slot ring
        lg, cache = model.decode_step(
            params, cache, toks[:, 32+i:33+i], jnp.asarray(32+i, jnp.int32)
        )
        assert np.isfinite(np.asarray(lg)).all()


def test_serve_engine_greedy_deterministic():
    cfg = reduced_config("deepseek-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng1 = ServeEngine(cfg, params, max_len=64)
    eng2 = ServeEngine(cfg, params, max_len=64)
    reqs1 = [Request(0, [5, 6, 7], max_new_tokens=8),
             Request(1, [9, 10], max_new_tokens=8)]
    reqs2 = [Request(0, [5, 6, 7], max_new_tokens=8),
             Request(1, [9, 10], max_new_tokens=8)]
    out1 = eng1.generate(reqs1)
    out2 = eng2.generate(reqs2)
    assert out1 == out2
    assert all(len(v) == 8 for v in out1.values())
    assert all(0 <= t < cfg.vocab_size for v in out1.values() for t in v)
