"""A-SRPT + baselines: scheduling invariants and end-to-end behaviour."""
import numpy as np
import pytest

pytestmark = pytest.mark.sched
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
)
from repro.core.cluster import ClusterState

from conftest import make_simple_job


def small_trace(n=60, seed=0, horizon=1800.0):
    cfg = TraceConfig(
        n_jobs=n, horizon=horizon, seed=seed, max_gpus_per_job=16,
        mean_iters=60, session_spread=30.0,
    )
    return generate_trace(cfg)


@pytest.fixture
def cluster():
    return ClusterSpec(
        num_servers=4, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )


def check_invariants(jobs, cluster, result):
    # all jobs completed exactly once
    assert set(result.records) == {j.job_id for j in jobs}
    by_id = {j.job_id: j for j in jobs}
    events = []
    for jid, rec in result.records.items():
        job = by_id[jid]
        # started after submission
        assert rec.start >= job.arrival - 1e-9
        # non-preemptive: completion = start + n_iters * alpha, alpha > 0
        assert rec.alpha > 0
        assert rec.completion == pytest.approx(
            rec.start + job.n_iters * rec.alpha
        )
        events.append((rec.start, job.g))
        events.append((rec.completion, -job.g))
    # GPU capacity never exceeded at any time (completions release their
    # GPUs before same-instant starts claim them)
    events.sort(key=lambda e: (e[0], e[1]))
    in_use = 0
    for _, delta in events:
        in_use += delta
        assert in_use <= cluster.total_gpus + 1e-9


@pytest.mark.parametrize(
    "policy_name", ["A-SRPT"] + list(BASELINES)
)
def test_invariants_all_policies(policy_name, cluster):
    jobs = small_trace(n=60, seed=3)
    if policy_name == "A-SRPT":
        pol = ASRPTPolicy(make_predictor("rf", seed=0), tau=2.0)
    else:
        pol = BASELINES[policy_name](make_predictor("rf", seed=0))
    result = simulate(jobs, cluster, pol)
    check_invariants(jobs, cluster, result)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_asrpt_invariants_random_seeds(seed):
    cluster = ClusterSpec(
        num_servers=3, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )
    jobs = small_trace(n=30, seed=seed)
    jobs = [j for j in jobs if j.g <= cluster.total_gpus]
    result = simulate(
        jobs, cluster, ASRPTPolicy(make_predictor("mean"), tau=1.0)
    )
    check_invariants(jobs, cluster, result)


def test_asrpt_determinism(cluster):
    jobs = small_trace(n=40, seed=7)
    r1 = simulate(jobs, cluster, ASRPTPolicy(make_predictor("perfect")))
    r2 = simulate(jobs, cluster, ASRPTPolicy(make_predictor("perfect")))
    for jid in r1.records:
        assert r1.records[jid].completion == r2.records[jid].completion


def test_asrpt_protects_short_jobs_from_long_backfill():
    """The paper's core mechanism, isolated: work-conserving baselines
    backfill long jobs onto every free GPU; later-arriving short jobs then
    wait behind non-preemptible work.  A-SRPT's virtual machine releases
    the long jobs gradually, keeping headroom for the shorts."""
    cluster = ClusterSpec(
        num_servers=10, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    jobs = []
    jid = 0
    # burst of long 8-GPU jobs at t=0 (enough to fill the cluster)
    for i in range(10):
        jobs.append(make_simple_job(
            job_id=jid, replicas=(8,), p=1.0, h_mb=1.0, n_iters=1000,
            arrival=0.0, group_id=1,
        ))
        jid += 1
    # steady stream of short single-GPU jobs arriving afterwards
    for i in range(100):
        jobs.append(make_simple_job(
            job_id=jid, replicas=(1,), p=1.0, h_mb=0.1, n_iters=20,
            arrival=10.0 + 5.0 * i, group_id=2,
        ))
        jid += 1
    flow = {}
    for name, pol in [
        ("asrpt", ASRPTPolicy(make_predictor("perfect"), tau=2.0)),
        ("wcs", BASELINES["WCS-SubTime"](make_predictor("perfect"))),
    ]:
        flow[name] = simulate(jobs, cluster, pol).total_flow_time
    assert flow["asrpt"] < 0.7 * flow["wcs"], flow


def test_comm_heavy_job_delayed_for_consolidation(cluster):
    """A comm-heavy job facing fragmented GPUs waits (up to tau budget)."""
    # occupy servers so only fragments remain: 4 single-GPU long jobs
    fillers = [
        make_simple_job(job_id=i, replicas=(1,), p=1.0, h_mb=0.1,
                        n_iters=100, arrival=0.0)
        for i in range(4)
    ]
    heavy = make_simple_job(
        job_id=99, replicas=(8,), p=0.05, h_mb=2048.0, n_iters=10,
        arrival=1.0, group_id=1,
    )
    pol = ASRPTPolicy(make_predictor("perfect"), tau=5.0)
    result = simulate(fillers + [heavy], cluster, pol)
    rec = result.records[99]
    # must be on as few servers as possible given 8 free GPUs per 3 servers
    assert rec.start >= 1.0
    check_invariants(fillers + [heavy], cluster, result)


def test_cluster_state_bookkeeping():
    spec = ClusterSpec(num_servers=2, gpus_per_server=4, b_inter=1e9, b_intra=1e10)
    cs = ClusterState(spec)
    assert cs.total_free == 8
    cs.allocate(1, {0: np.array([2, 1])})
    assert cs.free[0] == 1
    with pytest.raises(ValueError):
        cs.allocate(2, {0: np.array([2, 0])})
    cs.release(1)
    assert cs.total_free == 8
    cs.mark_server_down(0)
    assert cs.total_free == 4
