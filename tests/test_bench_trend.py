"""benchmarks/bench_trend.py: BENCH_sched.json artifact aggregation."""
import json
import os
import time

import pytest

pytestmark = pytest.mark.sched

bench_trend = pytest.importorskip(
    "benchmarks.bench_trend",
    reason="benchmarks namespace package needs the repo root on sys.path",
)


def _write(path, eps, mtime=None):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(
        json.dumps(
            {"schema": 1, "bench": "sched_scale_budget",
             "events_per_sec": eps, "rows": []}
        )
    )
    if mtime is not None:
        os.utime(path, (mtime, mtime))


def test_trend_series_ordering_and_gaps(tmp_path):
    now = time.time()
    _write(
        tmp_path / "run1" / "BENCH_sched.json",
        {"A-SRPT": 100.0, "SPJF": 50.0}, mtime=now - 100,
    )
    _write(
        tmp_path / "run2" / "BENCH_sched.json",
        {"A-SRPT": 110.0, "NewPolicy": 7.0}, mtime=now - 50,
    )
    (tmp_path / "run3").mkdir()
    corrupt = tmp_path / "run3" / "BENCH_sched.json"
    corrupt.write_text("{not json")
    os.utime(corrupt, (now - 25, now - 25))
    _write(
        tmp_path / "run4" / "BENCH_sched.json",
        {"A-SRPT": 120.0, "SPJF": 55.0}, mtime=now,
    )

    files = bench_trend.discover([str(tmp_path)])
    assert [f.parent.name for f in files] == ["run1", "run2", "run3", "run4"]
    labels, series = bench_trend.load_series(files)
    # the corrupt artifact is skipped, order is mtime-ascending
    assert labels == [
        "run1/BENCH_sched.json",
        "run2/BENCH_sched.json",
        "run4/BENCH_sched.json",
    ]
    assert series["A-SRPT"] == [100.0, 110.0, 120.0]
    assert series["SPJF"] == [50.0, None, 55.0]  # absent run padded
    assert series["NewPolicy"] == [None, 7.0, None]

    ratios = bench_trend.latest_vs_first(series)
    assert ratios["A-SRPT"] == 1.2
    assert ratios["SPJF"] == 1.1
    assert ratios["NewPolicy"] is None  # single point: no trend

    md = bench_trend.to_markdown(labels, series)
    lines = md.splitlines()
    assert lines[0].startswith("| policy |")
    assert any(line.startswith("| A-SRPT | 100 | 110 | 120 |") for line in lines)

    out = bench_trend.to_trend_json(labels, series)
    assert out["schema"] == 1 and out["artifacts"] == labels
    # round-trips through strict JSON (None -> null)
    assert json.loads(json.dumps(out)) == out


def test_trend_main_end_to_end(tmp_path, capsys):
    _write(tmp_path / "BENCH_sched_a.json", {"A-SRPT": 10.0})
    out_json = tmp_path / "trend.json"
    rc = bench_trend.main([str(tmp_path), "--json", str(out_json)])
    assert rc == 0
    assert "A-SRPT" in capsys.readouterr().out
    assert json.loads(out_json.read_text())["events_per_sec"] == {
        "A-SRPT": [10.0]
    }


def test_trend_no_artifacts(tmp_path):
    assert bench_trend.main([str(tmp_path)]) == 1
    with pytest.raises(FileNotFoundError):
        bench_trend.discover([str(tmp_path / "missing")])


def test_generated_at_overrides_mtime(tmp_path):
    """Downloaded artifacts all share a download mtime; the recorded
    generated_at run timestamp must win the ordering."""
    now = time.time()
    a = tmp_path / "runA" / "BENCH_sched.json"
    b = tmp_path / "runB" / "BENCH_sched.json"
    a.parent.mkdir()
    b.parent.mkdir()
    # runA ran LATER but was written to disk FIRST
    a.write_text(json.dumps({
        "schema": 1, "generated_at": "2026-07-28T12:00:00+00:00",
        "events_per_sec": {"A-SRPT": 200.0}, "rows": [],
    }))
    b.write_text(json.dumps({
        "schema": 1, "generated_at": "2026-07-28T09:00:00+00:00",
        "events_per_sec": {"A-SRPT": 100.0}, "rows": [],
    }))
    os.utime(a, (now - 100, now - 100))
    os.utime(b, (now, now))
    labels, series = bench_trend.load_series(bench_trend.discover([str(tmp_path)]))
    assert labels == ["runB/BENCH_sched.json", "runA/BENCH_sched.json"]
    assert series["A-SRPT"] == [100.0, 200.0]
    assert bench_trend.latest_vs_first(series)["A-SRPT"] == 2.0


def test_naive_generated_at_is_utc(tmp_path):
    f = tmp_path / "BENCH_sched.json"
    f.write_text("{}")
    naive = bench_trend._run_timestamp(f, {"generated_at": "2026-07-28T12:00:00"})
    aware = bench_trend._run_timestamp(
        f, {"generated_at": "2026-07-28T12:00:00+00:00"}
    )
    assert naive == aware


def test_latest_vs_first_requires_policy_in_newest_artifact():
    # dropped from the newest run: no trend headline (a stale point must
    # not read as the current ratio)
    assert bench_trend.latest_vs_first({"P": [50.0, 55.0, None]})["P"] is None
    assert bench_trend.latest_vs_first({"P": [50.0, None, 60.0]})["P"] == 1.2


def test_fleet_and_malformed_artifacts_skipped(tmp_path, capsys):
    """A fleet-schema artifact (different bench, no per-policy series)
    or a budget artifact with a malformed events_per_sec section must be
    skipped with a note, not crash or pollute the trend."""
    now = time.time()
    _write(tmp_path / "ok" / "BENCH_sched.json", {"A-SRPT": 100.0},
           mtime=now - 10)
    fleet = tmp_path / "fleet" / "BENCH_sched_fleet.json"
    fleet.parent.mkdir()
    fleet.write_text(json.dumps({
        "schema": 1, "bench": "sched_scale_fleet",
        "events_per_sec": {},  # even a matching key must not trend
        "digests": ["f" * 64], "stats": {},
    }))
    os.utime(fleet, (now - 5, now - 5))
    bad = tmp_path / "bad" / "BENCH_sched.json"
    bad.parent.mkdir()
    bad.write_text(json.dumps({
        "schema": 1, "bench": "sched_scale_budget",
        "events_per_sec": {"A-SRPT": "fast"},  # non-numeric
    }))
    os.utime(bad, (now, now))

    labels, series = bench_trend.load_series(
        bench_trend.discover([str(tmp_path)])
    )
    assert labels == ["ok/BENCH_sched.json"]
    assert series == {"A-SRPT": [100.0]}
    out = capsys.readouterr().out
    assert "sched_scale_fleet" in out and "malformed events_per_sec" in out


def test_min_ratio_gate(tmp_path, capsys):
    now = time.time()
    _write(tmp_path / "r1" / "BENCH_sched.json",
           {"A-SRPT": 100.0, "SPJF": 50.0, "Once": 10.0}, mtime=now - 10)
    _write(tmp_path / "r2" / "BENCH_sched.json",
           {"A-SRPT": 65.0, "SPJF": 55.0}, mtime=now)

    # A-SRPT at 0.65 < 0.7 fails the gate; "Once" (no ratio) never does
    assert bench_trend.main([str(tmp_path), "--min-ratio", "0.7"]) == 1
    out = capsys.readouterr().out
    assert "::error::trend gate: A-SRPT" in out
    assert "Once" in out and "gate skipped" in out

    assert bench_trend.main([str(tmp_path), "--min-ratio", "0.6"]) == 0
    assert "all latest/first ratios >= 0.6" in capsys.readouterr().out


def test_summary_appends_table(tmp_path):
    _write(tmp_path / "BENCH_sched_a.json", {"A-SRPT": 10.0})
    summary = tmp_path / "step_summary.md"
    summary.write_text("existing content\n")
    rc = bench_trend.main([str(tmp_path), "--summary", str(summary)])
    assert rc == 0
    text = summary.read_text()
    assert text.startswith("existing content\n")  # appended, not replaced
    assert "### sched_scale events/sec trend" in text
    assert "| A-SRPT |" in text
