"""Closed prediction loop (ISSUE 8): models, backoff, golden identity.

The prediction loop's foundational claim mirrors the fleet driver's:
new machinery must move *decisions*, never *results*, unless explicitly
armed.  These tests hold that claim three ways — a pass-through
``PredictionModel`` wrapper replays every golden schedule byte for
byte, an armed tracker fed *perfect* predictions still matches the
legacy engine (checks are elided when the prediction cannot fire
early), and the backoff re-estimator terminates in O(log n) checks for
arbitrarily wrong predictions — plus the noise models' determinism,
the fleet perturbation hook, hetero-aware selection, and the
``--predict`` CLI gate contracts.
"""
import json
import math
import pathlib

import pytest

pytestmark = pytest.mark.sched
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (  # noqa: E402
    ASRPTPolicy,
    NoisyModel,
    OnlineForestModel,
    OracleModel,
    PredictionModel,
    PredictionNoisePerturbation,
    Scenario,
    StragglerPerturbation,
    TraceConfig,
    ZeroColdStartModel,
    generate_trace,
    make_prediction_model,
    make_predictor,
    mixed_cluster_spec,
    run_fleet,
    simulate,
)
from repro.core.predictor import GroupStatPredictor, PerfectPredictor
from repro.core.simulator import AlphaCache  # noqa: E402
from conftest import make_simple_job  # noqa: E402

# pytest inserts the tests dir on sys.path (no tests/__init__.py)
import test_golden  # noqa: E402
from test_golden import SCENARIOS, load_jobs, run_scenario  # noqa: E402

sched_scale = pytest.importorskip(
    "benchmarks.sched_scale",
    reason="benchmarks namespace package needs the repo root on sys.path",
)

STRAGGLER_NAME = "A-SRPT (migrate) @het+straggler"


@pytest.fixture(scope="module")
def golden_jobs():
    return load_jobs()


@pytest.fixture(scope="module")
def expected():
    return json.loads(
        (pathlib.Path(__file__).resolve().parent / "golden" /
         "expected.json").read_text()
    )


# ---------------------------------------------------------------------------
# Golden byte-identity: pass-through wrappers and perfect-prediction tracking
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_wrapped_predictor_matches_all_goldens(
    name, golden_jobs, expected, monkeypatch
):
    """Wrapping the goldens' mean predictor in a ``track_overruns=False``
    ``PredictionModel`` replays every committed schedule byte for byte —
    the wrapper really is transparent, across every policy and
    clean/het/faulted/degraded scenario."""
    monkeypatch.setattr(
        test_golden, "make_predictor",
        lambda kind: PredictionModel(
            GroupStatPredictor(kind), track_overruns=False
        ),
    )
    got = run_scenario(name, golden_jobs)
    assert got["sha256"] == expected[name]["sha256"], name
    assert got["total_flow"] == expected[name]["total_flow"], name


def _straggler_run(policy):
    cluster_fn, _policy_fn, kwargs = SCENARIOS[STRAGGLER_NAME]
    jobs = load_jobs()
    return simulate(jobs, cluster_fn(), policy, **kwargs)


def test_perfect_predictions_with_tracking_are_identical():
    """Arming the tracker with *perfect* predictions changes nothing:
    pred checks are elided when the prediction cannot fire before the
    true completion, and the migration race sees identical remaining
    work — held on the migration-exercising straggler golden."""
    legacy = _straggler_run(
        ASRPTPolicy(PerfectPredictor(), tau=2.0, migrate=True,
                    migration_penalty=20.0)
    )
    tracked = _straggler_run(
        ASRPTPolicy(
            PredictionModel(PerfectPredictor(), track_overruns=True),
            tau=2.0, migrate=True, migration_penalty=20.0,
        )
    )
    assert tracked.schedule_digest() == legacy.schedule_digest()
    assert tracked.n_reestimates == 0


def test_oracle_model_is_the_perfect_predictor():
    a = _straggler_run(
        ASRPTPolicy(PerfectPredictor(), tau=2.0, migrate=True,
                    migration_penalty=20.0)
    )
    b = _straggler_run(
        ASRPTPolicy(OracleModel(), tau=2.0, migrate=True,
                    migration_penalty=20.0)
    )
    assert a.schedule_digest() == b.schedule_digest()
    assert b.n_reestimates == 0


# ---------------------------------------------------------------------------
# Backoff re-estimation: termination and the logarithmic check bound
# ---------------------------------------------------------------------------


def _backoff_checks(n_true: float, n_pred: float, model) -> int:
    """Pure mirror of the simulator's re-estimation loop: a check fires
    whenever elapsed work reaches the predicted total; the model answers
    a new total.  Returns the check count until the prediction covers
    the true work."""
    total = n_pred
    checks = 0
    while total < n_true:
        checks += 1
        assert checks < 200, "backoff loop failed to terminate"
        elapsed = total  # the job has exactly the predicted work done
        new_total = model.reestimate(None, elapsed)
        assert new_total > elapsed or new_total >= model.backoff_floor
        total = max(new_total, elapsed + 1e-9)
    return checks


@settings(max_examples=60, deadline=None)
@given(
    st.integers(1, 10**6),
    st.floats(0.0, 1e5),
    st.floats(1.25, 4.0),
)
def test_backoff_terminates_logarithmically(n_true, n_pred, factor):
    """Every (true, predicted) pair terminates, in at most
    ``log_factor(n_true) + 2`` checks once the floor is reached —
    regardless of how wrong (including 0) the initial prediction was."""
    model = PredictionModel(
        PerfectPredictor(), backoff_factor=factor, backoff_floor=1.0
    )
    checks = _backoff_checks(float(n_true), n_pred, model)
    bound = math.log(max(n_true, 2), factor) + 2
    assert checks <= bound


def test_prediction_model_validation():
    with pytest.raises(ValueError):
        PredictionModel(PerfectPredictor(), backoff_factor=1.0)
    with pytest.raises(ValueError):
        PredictionModel(PerfectPredictor(), backoff_floor=0.0)
    with pytest.raises(ValueError):
        NoisyModel(mode="gaussian")
    with pytest.raises(ValueError):
        NoisyModel(mode="coldstart", cold_frac=1.5)
    with pytest.raises(ValueError):
        make_prediction_model("nope")


def _small_scenario(n_jobs=120, seed=5):
    cluster = mixed_cluster_spec(num_servers=8, seed=0)
    jobs = [
        j for j in generate_trace(
            TraceConfig(
                n_jobs=n_jobs, horizon=n_jobs * 40.0, seed=seed,
                single_gpu_frac=0.4, max_gpus_per_job=16,
            )
        ) if j.g <= cluster.total_gpus
    ]
    return jobs, cluster


def test_zero_cold_start_completes_with_bounded_reestimates():
    """The acceptance worst case: every job predicted 0, scheduled ASAP,
    yet every job completes and the per-job check count stays within the
    log2 backoff bound."""
    jobs, cluster = _small_scenario()
    res = simulate(
        jobs, cluster,
        ASRPTPolicy(ZeroColdStartModel(), tau=2.0, refine_mapping=False),
        validate=False,
    )
    assert res.n_jobs == len(jobs)
    assert len(res.records) == len(jobs)  # every job completed
    assert res.n_reestimates > 0
    bound = sum(math.log2(max(j.n_iters, 2)) + 2 for j in jobs)
    assert res.n_reestimates <= bound


def test_online_forest_closes_the_loop():
    """The forest model runs end to end, re-estimates its cold-start
    mispredictions, and learns: late recurrences of seen groups predict
    nonzero."""
    jobs, cluster = _small_scenario()
    model = OnlineForestModel(seed=0, retrain_every=40, n_estimators=5,
                              max_history=500)
    res = simulate(
        jobs, cluster,
        ASRPTPolicy(model, tau=2.0, refine_mapping=False),
        validate=False,
    )
    assert len(res.records) == len(jobs)
    assert res.n_reestimates > 0
    seen = [j for j in jobs if j.group_id >= 0
            and model.predict(j) > 0.0]
    assert seen, "forest never learned any group"


# ---------------------------------------------------------------------------
# Noise models: deterministic, order-independent error injection
# ---------------------------------------------------------------------------


def test_noisy_model_is_a_pure_function_of_seed_and_job():
    m1 = NoisyModel("lognormal", sigma=0.5, seed=3)
    m2 = NoisyModel("lognormal", sigma=0.5, seed=3)
    jobs = [make_simple_job(job_id=i, n_iters=100 + i) for i in range(20)]
    # call order / count must not matter
    a = [m1.predict(j) for j in jobs]
    for j in reversed(jobs):
        m2.predict(j)
    b = [m2.predict(j) for j in jobs]
    assert a == b
    assert all(x > 0 for x in a)
    # a different seed draws different noise
    m3 = NoisyModel("lognormal", sigma=0.5, seed=4)
    assert [m3.predict(j) for j in jobs] != a


def test_rankflip_inverts_the_ordering():
    m = NoisyModel("rankflip", scale=400.0)
    short = make_simple_job(job_id=1, n_iters=10)
    long = make_simple_job(job_id=2, n_iters=10_000)
    assert m.predict(short) > m.predict(long)


def test_coldstart_zeroes_a_fraction():
    m = NoisyModel("coldstart", cold_frac=0.4, seed=0)
    jobs = [make_simple_job(job_id=i, n_iters=500) for i in range(400)]
    preds = [m.predict(j) for j in jobs]
    zeros = sum(1 for p in preds if p == 0.0)
    assert 0.25 < zeros / len(jobs) < 0.55
    assert all(p in (0.0, 500.0) for p in preds)
    # exact at cold_frac=0: byte-equal to the truth
    exact = NoisyModel("coldstart", cold_frac=0.0)
    assert all(exact.predict(j) == float(j.n_iters) for j in jobs)


# ---------------------------------------------------------------------------
# Fleet integration: PredictionNoisePerturbation + shared degraded memo
# ---------------------------------------------------------------------------


def _fleet_base(golden_jobs):
    return Scenario(
        jobs=tuple(golden_jobs[:80]),
        cluster=mixed_cluster_spec(num_servers=8, seed=0),
        name="predbase",
    )


def test_prediction_noise_perturbation_is_deterministic(golden_jobs):
    base = _fleet_base(golden_jobs)
    perts = (
        StragglerPerturbation(n_stragglers=2),
        PredictionNoisePerturbation(mode="lognormal", sigma=0.6),
    )
    mk = lambda: ASRPTPolicy(  # noqa: E731
        make_predictor("mean"), tau=2.0, refine_mapping=False, migrate=True
    )
    a = run_fleet(base, mk, perts, 4, seed=7)
    b = run_fleet(base, mk, perts, 4, seed=7)
    assert a.digest() == b.digest()
    assert run_fleet(base, mk, perts, 4, seed=8).digest() != a.digest()
    with pytest.raises(ValueError):
        PredictionNoisePerturbation(mode="gaussian")


def test_policy_perturbation_rng_is_disjoint_from_event_stream(golden_jobs):
    """Adding an *exact* prediction perturbation (coldstart, cold_frac=0
    — predicts true counts, arms the tracker) leaves every variant's
    schedule untouched: the policy perturbation draws from its own rng
    substream, so event/job draws cannot shift, and exact predictions
    elide every check."""
    base = _fleet_base(golden_jobs)
    mk = lambda: ASRPTPolicy(  # noqa: E731
        make_predictor("perfect"), tau=2.0, refine_mapping=False,
        migrate=True,
    )
    events_only = (StragglerPerturbation(n_stragglers=2),)
    with_noise = events_only + (
        PredictionNoisePerturbation(mode="coldstart", cold_frac=0.0),
    )
    a = run_fleet(base, mk, events_only, 3, seed=11)
    b = run_fleet(base, mk, with_noise, 3, seed=11)
    assert [v.digest for v in a.variants] == [v.digest for v in b.variants]


def test_degraded_bounds_memo_is_shareable():
    """Two AlphaCache instances aliasing one content-addressed memo give
    the same degraded bounds as a private cache — and the second
    instance answers from the shared memo without recomputing."""
    from repro.core import ClusterState

    spec = mixed_cluster_spec(num_servers=8, seed=0)
    cluster = ClusterState(spec)
    cluster.set_server_speed(0, 0.25)
    cluster.set_server_speed(3, 0.5)
    job = make_simple_job(job_id=1, replicas=(2, 2), n_iters=100)

    private = AlphaCache(spec)
    want = private.bounds(job, cluster)

    shared: dict = {}
    a = AlphaCache(spec)
    a._deg_cache = shared
    b = AlphaCache(spec)
    b._deg_cache = shared
    assert a.bounds(job, cluster) == want
    assert shared, "degraded memo not populated"
    before = dict(shared)
    assert b.bounds(job, cluster) == want
    assert shared == before  # b hit a's entries; no new keys


# ---------------------------------------------------------------------------
# Heterogeneity-aware server selection (satellite: ROADMAP carry-over)
# ---------------------------------------------------------------------------


def test_hetero_selection_improves_mixed_cluster_flow():
    cluster = mixed_cluster_spec(num_servers=10, seed=1)
    jobs = [
        j for j in generate_trace(
            TraceConfig(
                n_jobs=300, horizon=300 * 30.0, seed=7,
                single_gpu_frac=0.3, max_gpus_per_job=16,
            )
        ) if j.g <= cluster.total_gpus
    ]

    def run(**kw):
        return simulate(
            jobs, cluster,
            ASRPTPolicy(make_predictor("mean"), tau=2.0,
                        refine_mapping=False, **kw),
            validate=False,
        )

    default = run()
    scored = run(hetero_selection=True)
    assert len(scored.records) == len(jobs)
    # class-aware scoring must not lose to blind consolidation here
    assert scored.total_flow_time < default.total_flow_time
    # off by default: omitting the flag is the golden-pinned engine
    assert run().schedule_digest() == default.schedule_digest()


def test_hetero_selection_noop_on_homogeneous_clusters(
    golden_jobs, expected
):
    """On a homogeneous cluster the flag binds to nothing: schedules
    equal the committed golden byte for byte even with it on."""
    res = simulate(
        golden_jobs, test_golden._hom_cluster(),
        ASRPTPolicy(make_predictor("mean"), tau=2.0,
                    hetero_selection=True),
    )
    assert res.schedule_digest() == expected["A-SRPT @hom"]["sha256"]


# ---------------------------------------------------------------------------
# --predict benchmark: verdict function + CLI exit codes + baseline regime
# ---------------------------------------------------------------------------


def test_check_predict_regression_verdicts():
    check = sched_scale.check_predict_regression
    base = {
        "n_jobs": 2000,
        "forest_gate": 1.3,
        "ratios": {
            "forest": {"flow_vs_oracle": 1.0, "p95_vs_oracle": 1.0},
            "rankflip": {"flow_vs_oracle": 1.1, "p95_vs_oracle": 0.96},
        },
    }
    same = json.loads(json.dumps(base))

    errors, warnings, notes = check(same, base)
    assert not errors and not warnings
    assert any("gate" in n for n in notes)

    # forest over the absolute gate: hard error, baseline-independent
    hot = json.loads(json.dumps(base))
    hot["ratios"]["forest"]["p95_vs_oracle"] = 1.44
    errors, _, _ = check(hot, base)
    assert len(errors) == 1 and "1.44" in errors[0]
    errors, _, _ = check(hot, {})  # even with no baseline at all
    assert len(errors) == 1

    # missing forest regime: error (the gate cannot be skipped silently)
    noforest = json.loads(json.dumps(base))
    del noforest["ratios"]["forest"]
    errors, _, _ = check(noforest, base)
    assert errors

    # drift past the threshold: warning, not error
    drift = json.loads(json.dumps(base))
    drift["ratios"]["rankflip"]["p95_vs_oracle"] = 1.5
    errors, warnings, _ = check(drift, base, threshold=0.15)
    assert not errors and len(warnings) == 1 and "rankflip" in warnings[0]

    # regime mismatch / malformed baseline: notes only
    other = json.loads(json.dumps(base))
    other["n_jobs"] = 99
    errors, warnings, notes = check(other, base)
    assert not errors and not warnings
    assert any("n_jobs" in n for n in notes)
    errors, warnings, notes = check(same, {"ratios": None})
    assert not errors and not warnings


def _shrink_predict_regime(monkeypatch, gate=1e9):
    monkeypatch.setattr(sched_scale, "PREDICT_JOBS", 120)
    monkeypatch.setattr(sched_scale, "PREDICT_FOREST_GATE", gate)
    monkeypatch.setattr(
        sched_scale, "PREDICT_REGIMES",
        (
            ("oracle", "oracle", {}),
            ("forest", "forest",
             {"seed": 0, "retrain_every": 40, "n_estimators": 3,
              "max_history": 500}),
            ("lognormal-0.7", "lognormal", {"sigma": 0.7, "seed": 0}),
        ),
    )


def test_predict_cli_exit_codes(tmp_path, monkeypatch):
    main = sched_scale.main
    _shrink_predict_regime(monkeypatch)  # gate wide open: exit codes only
    out = tmp_path / "BENCH_predict.json"
    assert main(["--predict", "--json", str(out)]) == 0
    current = json.loads(out.read_text())
    assert current["bench"] == "sched_scale_predict"
    assert set(current["ratios"]) == {"forest", "lognormal-0.7"}
    assert len(current["oracle_sha256"]) == 64

    # self-check passes, strict or not
    assert main(["--predict", "--check", str(out)]) == 0
    assert main(["--predict", "--check", str(out), "--strict"]) == 0

    # ratio drift: warning by default, failure under --strict
    drift = json.loads(out.read_text())
    drift["ratios"]["lognormal-0.7"]["p95_vs_oracle"] /= 10.0
    drift_p = tmp_path / "drift.json"
    drift_p.write_text(json.dumps(drift))
    assert main(["--predict", "--check", str(drift_p)]) == 0
    assert main(["--predict", "--check", str(drift_p), "--strict"]) == 1

    # the absolute forest gate: exit 1 even without --strict
    _shrink_predict_regime(monkeypatch, gate=1e-9)
    assert main(["--predict", "--check", str(out)]) == 1

    # --predict is its own variant; --json needs a tracked series
    with pytest.raises(SystemExit):
        main(["--predict", "--fleet", "3"])
    with pytest.raises(SystemExit):
        main(["--predict", "--budget"])
    with pytest.raises(SystemExit):
        main(["--json", "x.json"])


def test_committed_predict_baseline_matches_ci_regime():
    """The committed baseline must be regenerable by the CI command
    (`--predict`): same job count, the gate value, the acceptance
    regimes present, and the forest actually under its gate."""
    p = (
        pathlib.Path(__file__).resolve().parents[1]
        / "benchmarks" / "BENCH_predict_baseline.json"
    )
    data = json.loads(p.read_text())
    assert data["bench"] == "sched_scale_predict"
    assert data["n_jobs"] == sched_scale.PREDICT_JOBS
    assert data["forest_gate"] == sched_scale.PREDICT_FOREST_GATE
    assert len(data["oracle_sha256"]) == 64
    required = {"forest", "zero-cold-start", "rankflip"}
    assert required <= set(data["ratios"])
    assert any(r.startswith("lognormal-") for r in data["ratios"])
    for r, vals in data["ratios"].items():
        assert vals["flow_vs_oracle"] > 0 and vals["p95_vs_oracle"] > 0
    assert (
        data["ratios"]["forest"]["p95_vs_oracle"]
        <= sched_scale.PREDICT_FOREST_GATE
    )


def test_flow_percentile():
    jobs, cluster = _small_scenario(n_jobs=40)
    res = simulate(
        jobs, cluster,
        ASRPTPolicy(make_predictor("mean"), tau=2.0, refine_mapping=False),
        validate=False,
    )
    flows = sorted(r.completion - r.arrival for r in res.records.values())
    assert res.flow_percentile(0.0) == flows[0]
    assert res.flow_percentile(100.0) == flows[-1]
    import numpy as np

    assert res.flow_percentile(95.0) == pytest.approx(
        float(np.percentile(flows, 95.0))
    )
