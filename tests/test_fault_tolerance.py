"""Fault tolerance: heartbeats, stragglers, elastic re-meshing, and the
scheduler-side reaction to lost capacity."""
import numpy as np
import pytest

from repro.core import ClusterSpec, make_predictor, simulate, ASRPTPolicy
from repro.train.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    plan_elastic_mesh,
)

from conftest import make_simple_job


class TestHeartbeat:
    def test_detects_overdue(self):
        hb = HeartbeatMonitor(timeout=10.0)
        hb.beat(0, t=0.0)
        hb.beat(1, t=5.0)
        assert hb.failed(now=12.0) == [0]
        assert hb.healthy(now=12.0) == [1]
        hb.beat(0, t=13.0)
        assert hb.failed(now=14.0) == []


class TestStraggler:
    def test_flags_slow_host(self):
        sd = StragglerDetector(alpha=1.0, threshold=1.5)
        for host in range(4):
            sd.record(host, 1.0)
        sd.record(3, 2.5)
        assert sd.stragglers() == [3]

    def test_ewma_recovers(self):
        sd = StragglerDetector(alpha=0.5, threshold=1.5)
        for host in range(3):
            sd.record(host, 1.0)
        sd.record(2, 4.0)
        assert 2 in sd.stragglers()
        for _ in range(8):
            sd.record(2, 1.0)
        assert sd.stragglers() == []


class TestElasticMesh:
    def test_plan_shrinks_data_axis(self):
        assert plan_elastic_mesh(256, 16) == (16, 16)
        assert plan_elastic_mesh(240, 16) == (15, 16)
        assert plan_elastic_mesh(17, 16) == (1, 16)
        with pytest.raises(ValueError):
            plan_elastic_mesh(8, 16)

    def test_elastic_restore_onto_smaller_mesh(self, tmp_path):
        import jax

        if len(jax.devices()) < 2:
            pytest.skip("needs >1 host device (run under dryrun env)")

    def test_restore_resharded_single_device(self, tmp_path):
        """Re-sharding via device_put works even degenerately (1 device)."""
        import jax

        from repro.configs import reduced_config
        from repro.models import Model
        from repro.train import checkpoint
        from repro.train.fault_tolerance import elastic_restore
        from repro.launch.mesh import make_debug_mesh

        cfg = reduced_config("deepseek-7b")
        model = Model(cfg)
        from repro.train.train_step import init_train_state

        state = init_train_state(model, jax.random.PRNGKey(0))
        checkpoint.save(tmp_path, 3, state)
        template = jax.eval_shape(
            lambda k: init_train_state(model, k), jax.random.PRNGKey(0)
        )
        mesh = make_debug_mesh(1, model=1)
        restored, meta, shardings = elastic_restore(
            tmp_path, template, cfg, mesh
        )
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSchedulerReaction:
    def test_scheduler_avoids_downed_server(self):
        """After a server fails, no new placement touches it."""
        spec = ClusterSpec(
            num_servers=4, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
        )

        class FailingASRPT(ASRPTPolicy):
            def schedule(self, t, cluster):
                if t >= 100.0 and cluster.free.get(3, 0) > 0:
                    cluster.mark_server_down(3)  # failure detected
                return super().schedule(t, cluster)

        jobs = [
            make_simple_job(job_id=i, replicas=(2,), p=0.5, h_mb=1,
                            n_iters=30, arrival=float(i * 20))
            for i in range(12)
        ]
        pol = FailingASRPT(make_predictor("perfect"), tau=1.0)
        result = simulate(jobs, spec, pol)
        for jid, rec in result.records.items():
            if rec.start >= 100.0:
                assert 3 not in rec.servers, (jid, rec)
