"""Fault tolerance: heartbeats, stragglers, elastic re-meshing, and the
scheduler-side reaction to lost capacity."""
import numpy as np
import pytest

from repro.core import ClusterSpec, make_predictor, simulate, ASRPTPolicy
from repro.train.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    plan_elastic_mesh,
)

from conftest import make_simple_job


class TestHeartbeat:
    def test_detects_overdue(self):
        hb = HeartbeatMonitor(timeout=10.0)
        hb.beat(0, t=0.0)
        hb.beat(1, t=5.0)
        assert hb.failed(now=12.0) == [0]
        assert hb.healthy(now=12.0) == [1]
        hb.beat(0, t=13.0)
        assert hb.failed(now=14.0) == []


class TestStraggler:
    def test_flags_slow_host(self):
        sd = StragglerDetector(alpha=1.0, threshold=1.5)
        for host in range(4):
            sd.record(host, 1.0)
        sd.record(3, 2.5)
        assert sd.stragglers() == [3]

    def test_ewma_recovers(self):
        sd = StragglerDetector(alpha=0.5, threshold=1.5)
        for host in range(3):
            sd.record(host, 1.0)
        sd.record(2, 4.0)
        assert 2 in sd.stragglers()
        for _ in range(8):
            sd.record(2, 1.0)
        assert sd.stragglers() == []


class TestElasticMesh:
    def test_plan_shrinks_data_axis(self):
        assert plan_elastic_mesh(256, 16) == (16, 16)
        assert plan_elastic_mesh(240, 16) == (15, 16)
        assert plan_elastic_mesh(17, 16) == (1, 16)
        with pytest.raises(ValueError):
            plan_elastic_mesh(8, 16)

    def test_elastic_restore_onto_smaller_mesh(self, tmp_path):
        import jax

        if len(jax.devices()) < 2:
            pytest.skip("needs >1 host device (run under dryrun env)")

    def test_restore_resharded_single_device(self, tmp_path):
        """Re-sharding via device_put works even degenerately (1 device)."""
        import jax

        from repro.configs import reduced_config
        from repro.models import Model
        from repro.train import checkpoint
        from repro.train.fault_tolerance import elastic_restore
        from repro.launch.mesh import make_debug_mesh

        cfg = reduced_config("deepseek-7b")
        model = Model(cfg)
        from repro.train.train_step import init_train_state

        state = init_train_state(model, jax.random.PRNGKey(0))
        checkpoint.save(tmp_path, 3, state)
        template = jax.eval_shape(
            lambda k: init_train_state(model, k), jax.random.PRNGKey(0)
        )
        mesh = make_debug_mesh(1, model=1)
        restored, meta, shardings = elastic_restore(
            tmp_path, template, cfg, mesh
        )
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.sched
class TestDegradationInjection:
    """Edge cases of the fault/degradation event stream (ISSUE 4)."""

    def _spec(self, n=2):
        return ClusterSpec(
            num_servers=n, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
        )

    def _policy(self, tau=1.0, **kw):
        return ASRPTPolicy(make_predictor("perfect"), tau=tau, **kw)

    def test_event_at_t_zero(self):
        """A degradation at t=0 precedes same-timestamp arrivals: the very
        first placement already sees the stretched server.  (tau=0 — a
        stretched alpha makes the job look comm-heavy against its clean
        bounds, and a delay budget would defer the start.)"""
        spec = self._spec(n=1)
        job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=10,
                              arrival=0.0)
        clean = simulate([job], spec, self._policy(tau=0.0))
        deg = simulate(
            [job], spec, self._policy(tau=0.0),
            degradations=[(0.0, 0, 0.5)],
        )
        # same start instant (the A-SRPT virtual machine releases the job
        # identically), but the placement alpha is stretched from the
        # first pass — the event beat the arrival at the same timestamp
        assert deg.records[0].start == clean.records[0].start
        assert deg.records[0].alpha == clean.records[0].alpha / 0.5

    def test_multiple_events_one_server(self):
        """Successive factor changes compose: each re-timing uses the
        latest factor, and recovery restores the clean rate.  (SPJF
        starts the lone job at t=0; A-SRPT would hold it in the virtual
        machine past the event window.)"""
        from repro.core.baselines import spjf

        spec = self._spec(n=1)
        job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=400)
        clean = simulate([job], spec, spjf(make_predictor("perfect")))
        a0 = clean.records[0].alpha
        assert clean.records[0].start == 0.0
        res = simulate(
            [job], spec, spjf(make_predictor("perfect")),
            degradations=[(2.0, 0, 0.5), (4.0, 0, 0.25), (6.0, 0, 1.0)],
        )
        rec = res.records[0]
        assert rec.alpha == a0  # final factor is 1.0
        # iterations done by t=6: 2s at full, 2s at half, 2s at quarter
        iters_done = 2.0 / a0 + 2.0 / (a0 / 0.5) + 2.0 / (a0 / 0.25)
        expected_tail = (400.0 - iters_done) * a0
        assert rec.completion == pytest.approx(6.0 + expected_tail,
                                               rel=1e-12)

    def test_event_on_idle_vs_allocated_server(self):
        """Idle-server events re-time nothing but steer later placements;
        allocated-server events stretch the running job."""
        from repro.core.baselines import spjf

        spec = self._spec(n=2)
        job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=100)
        clean = simulate([job], spec, spjf(make_predictor("perfect")))
        assert clean.records[0].start == 0.0
        assert clean.records[0].servers == (0,)  # consolidates onto one
        # idle server slows: the running job is untouched
        idle = simulate(
            [job], spec, spjf(make_predictor("perfect")),
            degradations=[(1.0, 1, 0.25)],
        )
        assert idle.records[0].completion == clean.records[0].completion
        assert idle.records[0].alpha == clean.records[0].alpha
        # allocated server slows: the job stretches
        busy = simulate(
            [job], spec, spjf(make_predictor("perfect")),
            degradations=[(1.0, 0, 0.25)],
        )
        assert busy.records[0].completion > clean.records[0].completion

    def test_event_after_last_completion(self):
        """Events past the makespan drain without passes going wrong and
        the run still completes all jobs."""
        spec = self._spec(n=2)
        job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=10)
        clean = simulate([job], spec, self._policy())
        t_late = clean.records[0].completion + 1000.0
        late = simulate(
            [job], spec, self._policy(migrate=True, migration_penalty=1.0),
            degradations=[(t_late, 0, 0.5), (t_late + 1.0, 0, 0.0)],
        )
        assert late.records[0].completion == clean.records[0].completion
        assert late.n_migrations == 0

    def test_unknown_server_and_negative_factor_raise(self):
        spec = self._spec(n=2)
        job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=10)
        with pytest.raises(ValueError):
            simulate([job], spec, self._policy(),
                     degradations=[(1.0, 0, -0.5)])
        with pytest.raises(ValueError):
            simulate([job], spec, self._policy(),
                     degradations=[(1.0, 99, 0.5)])


class TestSchedulerReaction:
    def test_scheduler_avoids_downed_server(self):
        """After a server fails, no new placement touches it."""
        spec = ClusterSpec(
            num_servers=4, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
        )

        class FailingASRPT(ASRPTPolicy):
            def plan_pass(self, t, cluster):
                if t >= 100.0 and cluster.free.get(3, 0) > 0:
                    cluster.mark_server_down(3)  # failure detected
                return super().plan_pass(t, cluster)

        jobs = [
            make_simple_job(job_id=i, replicas=(2,), p=0.5, h_mb=1,
                            n_iters=30, arrival=float(i * 20))
            for i in range(12)
        ]
        pol = FailingASRPT(make_predictor("perfect"), tau=1.0)
        result = simulate(jobs, spec, pol)
        for jid, rec in result.records.items():
            if rec.start >= 100.0:
                assert 3 not in rec.servers, (jid, rec)
