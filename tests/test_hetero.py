"""Heterogeneous clusters: per-class placement cache, fault injection,
and the capacity-indexed work-conserving ready queue.

The contract mirrors tests/test_sched_cache.py: everything the incremental
engine skips or relabels must be provably unchanged, so cached A-SRPT on a
mixed-generation cluster must be *bit-identical* to exhaustive
re-evaluation, per-class relabeling must never move a placement onto a
server class it wasn't computed for, and the homogeneous path must be
byte-for-byte the PR-1 behavior (a single-class spec reproduces the flat
spec exactly).
"""
import bisect

import numpy as np
import pytest

pytestmark = pytest.mark.sched

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ASRPTPolicy,
    ClusterSpec,
    ServerClass,
    TraceConfig,
    generate_trace,
    make_predictor,
    mixed_cluster_spec,
    simulate,
)
from repro.core.baselines import QueuePolicy
from repro.core.cluster import ClusterState
from repro.core.heavy_edge import (
    PlacementCache,
    alpha_min_estimate,
    consolidated_caps,
    select_servers,
)

from conftest import make_simple_job

from test_sched_cache import _simulate_pair, assert_identical


def _small_trace(seed, n_jobs=40, max_g=16):
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=60.0 * n_jobs,
            seed=seed,
            max_gpus_per_job=max_g,
            mean_iters=60,
            session_spread=30.0,
        )
    )


# ---------------------------------------------------------------------------
# Spec model
# ---------------------------------------------------------------------------


def test_heterogeneous_spec_layout():
    spec = ClusterSpec.heterogeneous(
        [
            ServerClass(count=2, gpus_per_server=8, b_inter=12.5e9, name="a"),
            ServerClass(count=3, gpus_per_server=4, b_inter=1.25e9, name="b"),
        ],
        b_intra=300e9,
    )
    assert spec.num_servers == 5
    assert spec.gpus_per_server == 8  # max over classes
    assert spec.b_inter == 1.25e9  # min over classes
    assert spec.total_gpus == 2 * 8 + 3 * 4
    assert [spec.class_of(m) for m in range(5)] == [0, 0, 1, 1, 1]
    assert [spec.server_gpus(m) for m in range(5)] == [8, 8, 4, 4, 4]
    assert spec.server_geom(0) == (8, 12.5e9, 300e9)
    assert spec.server_geom(4) == (4, 1.25e9, 300e9)


def test_heterogeneous_spec_validation():
    cls = ServerClass(count=2, gpus_per_server=8, b_inter=1e9)
    with pytest.raises(ValueError):  # counts must sum to num_servers
        ClusterSpec(
            num_servers=3, gpus_per_server=8, b_inter=1e9, b_intra=1e10,
            server_classes=(cls,),
        )
    with pytest.raises(ValueError):  # gpus_per_server must be the class max
        ClusterSpec(
            num_servers=2, gpus_per_server=4, b_inter=1e9, b_intra=1e10,
            server_classes=(cls,),
        )
    with pytest.raises(ValueError):  # b_inter must be the class min
        ClusterSpec(
            num_servers=2, gpus_per_server=8, b_inter=2e9, b_intra=1e10,
            server_classes=(cls,),
        )


def test_mixed_cluster_spec_generator():
    for seed in range(8):
        spec = mixed_cluster_spec(num_servers=9, seed=seed, n_classes=3)
        assert spec.is_heterogeneous
        assert sum(c.count for c in spec.server_classes) == 9
        assert all(c.count >= 1 for c in spec.server_classes)
        assert spec.gpus_per_server == max(
            c.gpus_per_server for c in spec.server_classes
        )


def test_cluster_state_tracks_per_server_capacity():
    spec = ClusterSpec.heterogeneous(
        [
            ServerClass(count=1, gpus_per_server=8, b_inter=12.5e9),
            ServerClass(count=2, gpus_per_server=4, b_inter=1.25e9),
        ],
        b_intra=300e9,
    )
    cs = ClusterState(spec)
    assert cs.free == {0: 8, 1: 4, 2: 4}
    assert cs.total_free == 16
    with pytest.raises(ValueError):  # small server can't hold 5 GPUs
        cs.allocate(1, {1: np.array([5])})
    cs.allocate(1, {0: np.array([8])})
    cs.release(1)
    assert cs.free[0] == 8


def test_release_after_fault_forfeits_capacity():
    spec = ClusterSpec(
        num_servers=2, gpus_per_server=4, b_inter=1e9, b_intra=1e10
    )
    cs = ClusterState(spec)
    cs.allocate(7, {0: np.array([3]), 1: np.array([1])})
    cs.mark_server_down(0)
    assert cs.total_free == 3  # server 1's remaining GPUs only
    cs.release(7)
    # server 0's three GPUs are forfeited, server 1's one returns
    assert cs.free[0] == 0
    assert cs.free[1] == 4
    assert cs.total_free == 4
    assert cs.downed_servers == {0}


# ---------------------------------------------------------------------------
# Per-class placement cache
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_cached_equals_uncached_hetero(seed):
    """Bit-identical cached vs exhaustive A-SRPT on mixed-generation specs."""
    spec = mixed_cluster_spec(num_servers=6, seed=seed, n_classes=3)
    jobs = _small_trace(seed)
    ra, rb = _simulate_pair(jobs, spec)
    assert_identical(ra, rb)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_cached_equals_uncached_hetero_refined(seed):
    spec = mixed_cluster_spec(num_servers=5, seed=seed, n_classes=2)
    jobs = _small_trace(seed, n_jobs=30)
    ra, rb = _simulate_pair(jobs, spec, refine=True)
    assert_identical(ra, rb)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_cache_relabeling_respects_class_capacity(seed):
    """A relabeled hit never lands on a server whose class can't hold it.

    Random free-capacity states on a mixed spec; every placement the cache
    returns must fit each server's *own* class capacity (a cross-class
    relabel would overflow the small class or mis-price its bandwidth).
    """
    rng = np.random.default_rng(seed)
    spec = mixed_cluster_spec(num_servers=7, seed=seed, n_classes=3)
    cache = PlacementCache(spec)
    job8 = make_simple_job(job_id=0, replicas=(4, 4), h_mb=64.0)
    job6 = make_simple_job(job_id=1, replicas=(3, 3), h_mb=16.0)
    for _ in range(30):
        free = {
            m: int(rng.integers(0, spec.server_gpus(m) + 1))
            for m in range(spec.num_servers)
        }
        for job in (job8, job6):
            if sum(free.values()) < job.g:
                continue
            for consolidate in (True, False):
                caps = select_servers(
                    free, job.g, consolidate=consolidate, spec=spec
                )
                placement, _a = cache.map_job(job, caps)
                taken = dict(caps)
                for m, x in placement.items():
                    got = int(np.asarray(x).sum())
                    assert got <= spec.server_gpus(m), (m, got)
                    assert got <= free[m]
                    assert got == taken[m]


def test_cache_keys_distinguish_classes():
    """Same capacity shape on different classes must be distinct entries."""
    spec = ClusterSpec.heterogeneous(
        [
            ServerClass(count=2, gpus_per_server=8, b_inter=12.5e9),
            ServerClass(count=2, gpus_per_server=8, b_inter=1.25e9),
        ],
        b_intra=300e9,
    )
    job = make_simple_job(job_id=0, replicas=(4, 4), h_mb=256.0)
    cache = PlacementCache(spec)
    p0, a0 = cache.map_job(job, [(0, 8)])  # fast-NIC class
    p1, a1 = cache.map_job(job, [(2, 8)])  # slow-NIC class: new key
    assert cache.misses == 2 and cache.hits == 0
    # same class, different server: within-class relabeled hit
    p3, a3 = cache.map_job(job, [(1, 8)])
    assert cache.hits == 1
    assert a3 == a0
    assert set(p3) == {1} and np.array_equal(p3[1], p0[0])
    # fully co-located on one server: NIC doesn't matter, alphas agree
    assert a0 == pytest.approx(a1)
    # split across two servers: the slow class pays more
    _, a_fast = cache.map_job(job, [(0, 4), (1, 4)])
    _, a_slow = cache.map_job(job, [(2, 4), (3, 4)])
    assert a_slow > a_fast


def test_single_class_spec_equals_flat_spec():
    """A one-class heterogeneous spec is the homogeneous cluster: the
    engine must produce the PR-1 schedule byte for byte."""
    flat = ClusterSpec(
        num_servers=4, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    wrapped = ClusterSpec.heterogeneous(
        [ServerClass(count=4, gpus_per_server=8, b_inter=1.25e9)],
        b_intra=300e9,
    )
    jobs = _small_trace(3)
    for refine in (False, True):
        ra = simulate(
            jobs, flat,
            ASRPTPolicy(make_predictor("mean"), refine_mapping=refine),
        )
        rb = simulate(
            jobs, wrapped,
            ASRPTPolicy(make_predictor("mean"), refine_mapping=refine),
        )
        assert_identical(ra, rb)


def test_consolidated_caps_hetero_prefers_big_fast_servers():
    spec = ClusterSpec.heterogeneous(
        [
            ServerClass(count=2, gpus_per_server=4, b_inter=1.25e9),
            ServerClass(count=2, gpus_per_server=8, b_inter=12.5e9),
        ],
        b_intra=300e9,
    )
    job = make_simple_job(job_id=0, replicas=(6, 6), h_mb=64.0)  # g = 12
    caps = consolidated_caps(job, spec)
    # big (8-GPU) class first: ids 2, 3 hold 8 + 4
    assert caps == [(2, 8), (3, 4)]
    assert alpha_min_estimate(job, spec) > 0.0


def test_select_servers_bandwidth_tiebreak():
    spec = ClusterSpec.heterogeneous(
        [
            ServerClass(count=2, gpus_per_server=8, b_inter=1.25e9),
            ServerClass(count=2, gpus_per_server=8, b_inter=12.5e9),
        ],
        b_intra=300e9,
    )
    free = {0: 8, 1: 8, 2: 8, 3: 8}
    # comm-heavy consolidation: fastest NIC first despite higher ids
    assert select_servers(free, 16, consolidate=True, spec=spec) == [
        (2, 8), (3, 8),
    ]
    # fragmentation-aware: slowest NIC first, fast servers stay free
    assert select_servers(free, 4, consolidate=False, spec=spec) == [(0, 4)]
    # without the spec the homogeneous id-order tiebreak applies
    assert select_servers(free, 16, consolidate=True) == [(0, 8), (1, 8)]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_alpha_max_bounds_realized_alphas_hetero(seed):
    """alpha_max stays an upper bound for every placement the scheduler
    realizes on a mixed-generation cluster."""
    from repro.core.simulator import AlphaCache

    spec = mixed_cluster_spec(num_servers=6, seed=seed, n_classes=3)
    jobs = _small_trace(seed, n_jobs=25)
    res = simulate(jobs, spec, ASRPTPolicy(make_predictor("mean")))
    bounds = AlphaCache(spec)
    by_id = {j.job_id: j for j in jobs}
    for jid, rec in res.records.items():
        a_max, a_min = bounds.bounds(by_id[jid])
        assert rec.alpha <= a_max + 1e-9
        assert a_min <= a_max + 1e-9


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_fault_injection_avoids_downed_servers(seed):
    """No job is ever placed on a downed server; every job still finishes."""
    spec = mixed_cluster_spec(num_servers=6, seed=seed, n_classes=2)
    jobs = _small_trace(seed, n_jobs=30, max_g=8)
    fault_t = jobs[len(jobs) // 3].arrival
    downed = (0, spec.num_servers - 1)  # one big-class, one small-class
    res = simulate(
        jobs,
        spec,
        ASRPTPolicy(make_predictor("mean")),
        faults=[(fault_t, m) for m in downed],
    )
    assert len(res.records) == len(jobs)
    for jid, rec in res.records.items():
        if rec.start >= fault_t:
            assert not set(downed) & set(rec.servers), (jid, rec)


def test_fault_injection_work_conserving_baseline():
    spec = ClusterSpec(
        num_servers=4, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )
    jobs = [
        make_simple_job(job_id=i, replicas=(2,), p=0.5, h_mb=1,
                        n_iters=20, arrival=float(i * 5))
        for i in range(16)
    ]
    res = simulate(
        jobs,
        spec,
        QueuePolicy(make_predictor("perfect"), key="subtime",
                    work_conserving=True),
        faults=[(30.0, 2)],
    )
    assert len(res.records) == len(jobs)
    for jid, rec in res.records.items():
        if rec.start >= 30.0:
            assert 2 not in rec.servers, (jid, rec)


# ---------------------------------------------------------------------------
# Capacity-indexed work-conserving ready queue
# ---------------------------------------------------------------------------


class _LinearScanWCS(QueuePolicy):
    """Reference: the former O(queue) full-scan backfilling pass."""

    def on_arrival(self, t, job):
        bisect.insort(
            self.waiting, (-self._key(job), -job.arrival, -job.job_id, job)
        )

    def plan_pass(self, t, cluster):
        starts = []
        waiting = self.waiting
        if not waiting or cluster.total_free == 0:
            return starts
        started_idx = []
        for i in range(len(waiting) - 1, -1, -1):
            free = cluster.total_free
            if free == 0:
                break
            job = waiting[i][3]
            if job.g <= free:
                self._start(job, cluster, starts)
                started_idx.append(i)
        for i in started_idx:  # descending, so positions stay valid
            del waiting[i]
        return starts

    def queue_depth(self):
        return len(self.waiting)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 10_000),
    st.sampled_from(["duration", "workload", "subtime"]),
)
def test_bucketed_wcs_equals_linear_scan(seed, key):
    specs = (
        ClusterSpec(
            num_servers=4, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
        ),
        mixed_cluster_spec(num_servers=6, seed=seed, n_classes=3),
    )
    jobs = _small_trace(seed, n_jobs=60)
    for spec in specs:
        ra = simulate(
            jobs, spec,
            QueuePolicy(make_predictor("mean"), key=key,
                        work_conserving=True),
        )
        rb = simulate(
            jobs, spec,
            _LinearScanWCS(make_predictor("mean"), key=key,
                           work_conserving=True),
        )
        assert_identical(ra, rb)


def test_bucketed_queue_depth_tracking():
    pol = QueuePolicy(make_predictor("mean"), key="subtime",
                      work_conserving=True)
    spec = ClusterSpec(
        num_servers=2, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    pol.bind(spec)
    cluster = ClusterState(spec)
    for i in range(5):
        pol.on_arrival(float(i), make_simple_job(job_id=i, replicas=(2,)))
    assert pol.queue_depth() == 5
    started = pol.schedule(5.0, cluster)
    assert len(started) == 5  # 5 x 2 GPUs fit in 16
    assert pol.queue_depth() == 0
