"""Pallas flash-attention kernel vs pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(B, Sq, T, H, G, K, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, K)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, G, K)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, G, K)), dtype)
    qpos = jnp.arange(T - Sq, T, dtype=jnp.int32)
    kpos = jnp.arange(T, dtype=jnp.int32)
    return q, k, v, qpos, kpos


SHAPE_SWEEP = [
    # (B, Sq, T, H, G, K)
    (1, 128, 128, 4, 4, 128),   # MHA
    (2, 256, 256, 8, 2, 128),   # GQA 4:1
    (1, 128, 128, 4, 1, 128),   # MQA
    (1, 128, 384, 4, 2, 128),   # cache longer than queries
    (2, 128, 128, 4, 2, 64),    # small head dim
    (1, 512, 512, 2, 2, 128),   # longer seq
]


@pytest.mark.parametrize("shape", SHAPE_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(shape, dtype):
    B, Sq, T, H, G, K = shape
    q, k, v, qpos, kpos = _mk(B, Sq, T, H, G, K, dtype)
    out = ops.flash_attention(q, k, v, qpos, kpos, True, None)
    want = ref.flash_attention_ref(q, k, v, qpos, kpos, True, None)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("window", [32, 96, 128])
def test_sliding_window(window):
    q, k, v, qpos, kpos = _mk(1, 256, 256, 4, 2, 128, jnp.float32)
    out = ops.flash_attention(q, k, v, qpos, kpos, True, window)
    want = ref.flash_attention_ref(q, k, v, qpos, kpos, True, window)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_non_causal_encoder():
    q, k, v, qpos, kpos = _mk(2, 128, 128, 4, 4, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, qpos, kpos, False, None)
    want = ref.flash_attention_ref(q, k, v, qpos, kpos, False, None)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_masked_empty_slots():
    """Ring-buffer slots with pos=-1 must be ignored."""
    q, k, v, qpos, kpos = _mk(1, 128, 256, 4, 2, 128, jnp.float32)
    kpos = kpos.at[200:].set(-1)
    out = ops.flash_attention(q, k, v, qpos, kpos, True, None)
    want = ref.flash_attention_ref(q, k, v, qpos, kpos, True, None)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_gradients_match_ref():
    q, k, v, qpos, kpos = _mk(1, 128, 128, 4, 2, 128, jnp.float32)

    def f(fn):
        def loss(q, k, v):
            return (fn(q, k, v, qpos, kpos, True, None) ** 2).sum()
        return jax.grad(loss, argnums=(0, 1, 2))

    g_ker = f(ops.flash_attention)(q, k, v)
    g_ref = f(ref.flash_attention_ref)(q, k, v)
    for a, b in zip(g_ker, g_ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4
        )
