"""End-to-end system behaviour: the scheduler schedules the same models the
framework trains; training + serving run under scheduler-chosen order."""
import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import (
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    TraceConfig,
    generate_trace,
    job_from_model_shape,
    make_predictor,
    simulate,
)
from repro.launch.train import train_loop
from repro.models import Model


def test_framework_arch_as_scheduler_job():
    """Bridge: a qwen3-32b training job (our framework's config) becomes a
    DDLwMP job the paper's scheduler can place."""
    cfg = get_config("qwen3-32b")
    specs = Model(cfg).param_specs()
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(specs))
    job = job_from_model_shape(
        job_id=0, name=cfg.name, total_params=total, d_model=cfg.d_model,
        global_batch=256, seq_len=4096, replicas=(2, 2, 2, 2), n_iters=100,
    )
    assert job.g == 8
    cluster = ClusterSpec(
        num_servers=4, gpus_per_server=8, b_inter=25e9, b_intra=600e9
    )
    result = simulate([job], cluster, ASRPTPolicy(make_predictor("perfect")))
    rec = result.records[0]
    assert rec.alpha > 0 and rec.completion > 0
    # consolidated on a single 8-GPU server (heavy-edge finds it)
    assert len(rec.servers) == 1


def test_scheduler_end_to_end_mixed_policies():
    cluster = ClusterSpec(
        num_servers=6, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    jobs = generate_trace(
        TraceConfig(n_jobs=120, horizon=7200.0, seed=11,
                    max_gpus_per_job=16, mean_iters=80)
    )
    totals = {}
    for name in ["A-SRPT", "WCS-SubTime", "SPJF"]:
        pol = (
            ASRPTPolicy(make_predictor("rf", seed=0))
            if name == "A-SRPT"
            else BASELINES[name](make_predictor("rf", seed=0))
        )
        res = simulate(jobs, cluster, pol)
        totals[name] = res.total_flow_time
        assert len(res.records) == len(jobs)
    assert all(v > 0 for v in totals.values())


def test_train_then_serve_roundtrip(tmp_path):
    """Train a reduced model briefly, checkpoint, reload, serve greedily."""
    from repro.serve.engine import Request, ServeEngine
    from repro.train import checkpoint
    from repro.train.train_step import init_train_state
    from repro.models import Model

    res = train_loop(
        "deepseek-7b", steps=8, batch=2, seq=32,
        ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100,
    )
    assert np.isfinite(res["last_loss"])
    cfg = reduced_config("deepseek-7b")
    model = Model(cfg)
    template = jax.eval_shape(
        lambda k: init_train_state(model, k), jax.random.PRNGKey(0)
    )
    state, meta = checkpoint.restore(tmp_path, template)
    eng = ServeEngine(cfg, state.params, max_len=48)
    out = eng.generate([Request(0, [1, 2, 3], max_new_tokens=5)])
    assert len(out[0]) == 5
