"""AdamW from scratch: convergence, clipping, schedule, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress,
    compress_decompress_with_feedback,
    cosine_lr,
    decompress,
    global_norm,
    zeros_like_error,
)


def test_converges_on_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_clip_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0, lr_peak=1.0, warmup_steps=0,
                      total_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    huge = {"w": jnp.array([1e6, 1e6, 1e6])}
    _, _, metrics = adamw_update(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) > 1e6  # raw norm reported
    # post-clip effective grad has norm 1 -> m bounded
    # (indirect: update magnitude is bounded by lr)


def test_cosine_schedule_endpoints():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(cosine_lr(cfg, jnp.asarray(100))) == pytest.approx(0.0, abs=1e-9)


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=1.0)
    params = {"mat": jnp.ones((2, 2)), "scale": jnp.ones((4,))}
    state = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw_update(cfg, params, zeros, state)
    assert float(jnp.max(jnp.abs(new["mat"]))) < 1.0  # decayed
    np.testing.assert_allclose(np.asarray(new["scale"]), 1.0)  # exempt


class TestCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
        q, s = compress(g)
        back = decompress(q, s)
        assert float(jnp.max(jnp.abs(back - g))) <= float(s) / 2 + 1e-6

    def test_error_feedback_is_unbiased_over_time(self):
        """Accumulated compressed sum ~= accumulated true sum."""
        rng = np.random.default_rng(1)
        grads_seq = [
            {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
            for _ in range(50)
        ]
        err = zeros_like_error(grads_seq[0])
        acc_hat = jnp.zeros(64)
        acc_true = jnp.zeros(64)
        for g in grads_seq:
            ghat, err = compress_decompress_with_feedback(g, err)
            acc_hat = acc_hat + ghat["w"]
            acc_true = acc_true + g["w"]
        # residual bounded by one quantization step, not O(T) drift
        resid = float(jnp.max(jnp.abs(acc_hat - acc_true)))
        per_step = float(jnp.max(jnp.abs(grads_seq[0]["w"]))) / 127
        assert resid < per_step * 4


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
