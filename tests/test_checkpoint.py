"""Checkpoint: atomic roundtrip, async writer, pruning, exact resume."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.train import train_loop
from repro.models import Model
from repro.train import checkpoint
from repro.train.train_step import init_train_state


@pytest.fixture
def tmp_ckpt(tmp_path):
    return tmp_path / "ckpt"


def test_roundtrip_bit_exact(tmp_ckpt):
    cfg = reduced_config("qwen3-32b")
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    checkpoint.save(tmp_ckpt, 7, state, {"loader": {"step": 7, "seed": 0}})
    template = jax.eval_shape(
        lambda k: init_train_state(model, k), jax.random.PRNGKey(0)
    )
    restored, meta = checkpoint.restore(tmp_ckpt, template)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_ckpt):
    cfg = reduced_config("mamba2-370m")
    state = init_train_state(Model(cfg), jax.random.PRNGKey(0))
    for s in (10, 20, 30, 40):
        checkpoint.save(tmp_ckpt, s, state)
    assert checkpoint.latest_step(tmp_ckpt) == 40
    checkpoint.prune(tmp_ckpt, keep=2)
    assert checkpoint.latest_step(tmp_ckpt) == 40
    assert not (tmp_ckpt / "step_10").exists()
    assert (tmp_ckpt / "step_30").exists()


def test_incomplete_checkpoint_ignored(tmp_ckpt):
    cfg = reduced_config("mamba2-370m")
    state = init_train_state(Model(cfg), jax.random.PRNGKey(0))
    checkpoint.save(tmp_ckpt, 5, state)
    # simulate a torn write: step_9 without the commit marker
    (tmp_ckpt / "step_9").mkdir()
    assert checkpoint.latest_step(tmp_ckpt) == 5


def test_async_writer(tmp_ckpt):
    cfg = reduced_config("mamba2-370m")
    state = init_train_state(Model(cfg), jax.random.PRNGKey(0))
    w = checkpoint.AsyncWriter(tmp_ckpt, keep=2)
    for s in (1, 2, 3):
        w.submit(s, state, {"loader": {"step": s, "seed": 0}})
    w.close()
    assert checkpoint.latest_step(tmp_ckpt) == 3


def test_resume_is_exact(tmp_path):
    """Crash at step 12, resume: final state equals uninterrupted run."""
    kw = dict(steps=16, batch=2, seq=32, ckpt_every=4, log_every=100)
    d1 = str(tmp_path / "a")
    with pytest.raises(RuntimeError):
        train_loop("mamba2-370m", ckpt_dir=d1, fail_at=12, **kw)
    res_resumed = train_loop("mamba2-370m", ckpt_dir=d1, **kw)
    res_straight = train_loop("mamba2-370m", ckpt_dir=str(tmp_path / "b"), **kw)
    assert res_resumed["last_loss"] == pytest.approx(
        res_straight["last_loss"], rel=1e-5
    )
