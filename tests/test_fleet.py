"""Scenario fleets (ISSUE 7): shared-cache driver bit-identity + gates.

The fleet driver's whole value rests on one claim: sharing placement /
alpha caches across variants and batch-prewarming the cold refine
working set moves *work*, never *results*.  These tests hold that claim
against the strongest available references — the sequential
``simulate()`` path on every golden scenario, and a cold cache on the
exact warm request list — plus the determinism and exit-code contracts
the CI fleet-robustness job depends on.
"""
import json

import pytest

pytestmark = pytest.mark.sched

from repro.core import (  # noqa: E402
    ASRPTPolicy,
    ArrivalJitterPerturbation,
    ElasticPerturbation,
    Scenario,
    StragglerPerturbation,
    make_predictor,
    run_fleet,
    scenario_from_legacy,
    simulate,
)
from repro.core.fleet import FleetShared, _ScoutShared  # noqa: E402
from repro.core.heavy_edge import PlacementCache  # noqa: E402

# pytest inserts the tests dir on sys.path (no tests/__init__.py), so
# the golden matrix imports as a top-level module
from test_golden import (  # noqa: E402
    SCENARIOS,
    _het_cluster,
    _hom_cluster,
    load_jobs,
)

sched_scale = pytest.importorskip(
    "benchmarks.sched_scale",
    reason="benchmarks namespace package needs the repo root on sys.path",
)


@pytest.fixture(scope="module")
def golden_jobs():
    return load_jobs()


def _perturbations(n_stragglers=2, jitter=30.0, elastic=1):
    return (
        StragglerPerturbation(n_stragglers=n_stragglers),
        ElasticPerturbation(n_servers=elastic),
        ArrivalJitterPerturbation(sigma=jitter),
    )


def _mk_asrpt(**kw):
    return lambda: ASRPTPolicy(make_predictor("mean"), tau=2.0, **kw)


def test_same_seed_bit_identical(golden_jobs):
    """The whole FleetResult — per-variant sha256s and the fleet digest
    over them — is a pure function of (base, factory, perts, n, seed)."""
    base = Scenario(
        jobs=tuple(golden_jobs[:80]), cluster=_hom_cluster(), name="det"
    )
    mk = _mk_asrpt(refine_mapping=True, migrate=True)
    a = run_fleet(base, mk, _perturbations(), 4, seed=7)
    b = run_fleet(base, mk, _perturbations(), 4, seed=7)
    assert a.digest() == b.digest()
    assert [v.digest for v in a.variants] == [v.digest for v in b.variants]
    assert a.stats == b.stats
    # a different seed draws different perturbations
    c = run_fleet(base, mk, _perturbations(), 4, seed=8)
    assert c.digest() != a.digest()
    # the serialized form carries the same digests
    d = a.to_dict()
    assert d["digests"] == [v.digest for v in a.variants]
    assert d["fleet_digest"] == a.digest()


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_fleet_matches_sequential_on_goldens(name, golden_jobs):
    """Shared-cache + prewarmed fleet schedules == N independent
    ``simulate()`` calls, per variant, on every golden scenario matrix
    entry (clean/het/faulted/degraded, cached/uncached, all policies)."""
    cluster_fn, policy_fn, kwargs = SCENARIOS[name]
    base = scenario_from_legacy(
        golden_jobs, cluster_fn(),
        faults=kwargs.get("faults"),
        degradations=kwargs.get("degradations"),
        name=f"golden:{name}",
    )
    perts = _perturbations()
    fleet = run_fleet(base, policy_fn, perts, 2, seed=3)
    seq = run_fleet(
        base, policy_fn, perts, 2, seed=3, share=False, prewarm=False
    )
    assert [v.digest for v in fleet.variants] == [
        v.digest for v in seq.variants
    ], name
    assert fleet.digest() == seq.digest()
    # and the sequential arm really is the plain simulate() path
    from repro.core.fleet import fleet_variants

    for (_i, variant), v in zip(
        fleet_variants(base.materialize(), perts, 2, seed=3), seq.variants
    ):
        res = simulate(variant, policy_fn(), validate=False)
        assert res.schedule_digest() == v.digest, name


def test_warm_bit_identity(golden_jobs):
    """``PlacementCache.warm`` entries (refine batched across shapes)
    answer ``map_job`` exactly like a cold cache computing each miss
    on demand — placements and alpha floats byte-for-byte."""
    cluster = _het_cluster()
    base = Scenario(
        jobs=tuple(golden_jobs[:120]), cluster=cluster, name="warmtest"
    )
    shared = FleetShared(cluster)
    log = []
    probe = ASRPTPolicy(
        make_predictor("mean"), tau=2.0, refine_mapping=False, migrate=True
    )
    probe.fleet_shared = _ScoutShared(shared, log)
    simulate(base, probe, validate=False)
    assert log, "scout run recorded no placement misses"

    warm_pc = shared.placement_cache(cluster, refine=True)
    warmed, groups = warm_pc.warm(log)
    assert warmed > 0 and groups > 0
    def norm(result):
        placement, a = result
        return (
            {s: [int(x) for x in counts] for s, counts in placement.items()},
            a,  # exact float — no tolerance
        )

    cold_pc = PlacementCache(cluster, refine=True)
    for job, caps in log:
        assert norm(warm_pc.map_job(job, caps)) == norm(
            cold_pc.map_job(job, caps)
        )
    # idempotent: a second warm finds everything already cached
    assert warm_pc.warm(log) == (0, 0)


def test_check_fleet_regression_verdicts():
    check = sched_scale.check_fleet_regression
    base = {
        "seed": 0, "n_variants": 3,
        "digests": ["a" * 64, "b" * 64, "c" * 64],
        "stats": {"total_flow_time": {"p95": 100.0}},
    }
    same = json.loads(json.dumps(base))

    errors, warnings, notes = check(same, base)
    assert not errors and not warnings
    assert any("digests match" in n for n in notes)

    # p95 regression past the threshold is a warning, not an error
    slow = json.loads(json.dumps(base))
    slow["stats"]["total_flow_time"]["p95"] = 140.0
    errors, warnings, _ = check(slow, base, threshold=0.30)
    assert not errors and len(warnings) == 1
    assert "p95" in warnings[0]

    # any sha mismatch at the same regime is a hard error
    drift = json.loads(json.dumps(base))
    drift["digests"][1] = "d" * 64
    errors, warnings, _ = check(drift, base)
    assert len(errors) == 1 and "#v1" in errors[0]

    # different regime: sha check skipped with a note, never an error
    other = json.loads(json.dumps(base))
    other["seed"] = 9
    errors, _, notes = check(other, base)
    assert not errors
    assert any("regime" in n for n in notes)

    # malformed baseline: notes only
    errors, warnings, notes = check(same, {})
    assert not errors and not warnings and len(notes) == 2


def test_fleet_cli_exit_codes(tmp_path):
    main = sched_scale.main
    out = tmp_path / "BENCH_fleet.json"
    rc = main(["--fleet", "3", "--json", str(out)])
    assert rc == 0
    current = json.loads(out.read_text())
    assert current["bench"] == "sched_scale_fleet"
    assert len(current["digests"]) == 3 and current["n_variants"] == 3

    # self-check passes, strict or not
    assert main(["--fleet", "3", "--check", str(out)]) == 0
    assert main(["--fleet", "3", "--check", str(out), "--strict"]) == 0

    # sha drift: exit 1 even without --strict
    drift = json.loads(out.read_text())
    drift["digests"][0] = "0" * 64
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(drift))
    assert main(["--fleet", "3", "--check", str(bad)]) == 1

    # p95 regression: warning by default, failure under --strict
    slow = json.loads(out.read_text())
    slow["stats"]["total_flow_time"]["p95"] /= 2.0
    del slow["digests"]  # isolate the stats check
    slow_p = tmp_path / "slow.json"
    slow_p.write_text(json.dumps(slow))
    assert main(["--fleet", "3", "--check", str(slow_p)]) == 0
    assert main(["--fleet", "3", "--check", str(slow_p), "--strict"]) == 1

    # unreadable baseline: fail-soft by default, strict fails
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{nope")
    assert main(["--fleet", "3", "--check", str(corrupt)]) == 0
    assert main(["--fleet", "3", "--check", str(corrupt), "--strict"]) == 1

    # --strict without --check is an argparse error
    with pytest.raises(SystemExit):
        main(["--fleet", "3", "--strict"])


def test_committed_fleet_baseline_matches_ci_regime():
    """The committed baseline must be regenerable by the CI command:
    same seed, variant count, and schema the fleet-robustness job uses
    (`--fleet 64`); per-variant digests present for the bit-identity
    gate."""
    import pathlib

    p = (
        pathlib.Path(__file__).resolve().parents[1]
        / "benchmarks" / "BENCH_fleet_baseline.json"
    )
    data = json.loads(p.read_text())
    assert data["bench"] == "sched_scale_fleet"
    assert data["seed"] == 0
    assert data["n_variants"] == sched_scale.FLEET_VARIANTS_DEFAULT
    assert len(data["digests"]) == data["n_variants"]
    assert all(
        isinstance(d, str) and len(d) == 64 for d in data["digests"]
    )
    assert data["stats"]["total_flow_time"]["p95"] > 0
