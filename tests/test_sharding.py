"""Sharding rules: every generated spec is valid for its tensor, and the
dry-run pipeline works end-to-end on a multi-device (subprocess) mesh."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import Model
from repro.parallel import sharding as sh


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_divisible(arch):
    """Each sharded dim must be divisible by its mesh-axis extent."""
    cfg = get_config(arch)
    mesh = make_debug_mesh(1, model=1)  # placeholder mesh for rule lookup

    # emulate the production mesh extents without device allocation
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    fake = FakeMesh()
    specs = Model(cfg).param_specs()

    def check(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        spec = sh._param_spec(keys, leaf, cfg, fake)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            extent = int(np.prod([fake.shape[a] for a in axes]))
            assert dim % extent == 0, (keys, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, specs)


def test_batch_axes_divisibility_fallback():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    m = FakeMesh()
    assert sh.batch_axes(m, 256) == ("pod", "data")
    assert sh.batch_axes(m, 16) == "data"
    assert sh.batch_axes(m, 1) is None
    assert sh.maybe(m, 24, "model") is None  # not divisible -> replicate


DRYRUN_SNIPPET = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_debug_mesh
    from repro.configs import reduced_config
    mesh = make_debug_mesh(8, model=2)
    for arch in ("qwen3-32b", "jamba-1.5-large-398b"):
        cfg = reduced_config(arch, dtype="bfloat16")
        res = run_cell(arch, "train_4k", False, verbose=False,
                       mesh=mesh, cfg=cfg)
        assert res["ok"], res
        assert res["hlo_flops"] > 0 and res["coll_bytes"] > 0
    print("DRYRUN_SUBPROCESS_OK")
    """
)


def test_dryrun_pipeline_multidevice():
    """lower+compile+analyze on an 8-device mesh (fresh process so the
    device-count flag applies)."""
    proc = subprocess.run(
        [sys.executable, "-c", DRYRUN_SNIPPET],
        capture_output=True,
        text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "DRYRUN_SUBPROCESS_OK" in proc.stdout, proc.stderr[-2000:]
