"""Batched-serving correctness: batch isolation, budgets, EOS, refill.

The headline property (ISSUE 9 satellite): a request's greedy output is
bit-identical whether it is served alone or batched with arbitrary
batch-mates of different prompt lengths — the old left-pad prefill leaked
pad positions across rows, so outputs depended on batch composition.
"""
import jax
import pytest

from repro.configs import reduced_config
from repro.models import Model
from repro.serve.engine import Request, ServeEngine


def _engine(arch="deepseek-7b", **kw):
    cfg = reduced_config(arch)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params, ServeEngine(cfg, params, max_len=64, **kw)


PROMPTS = [[5, 6, 7], [9, 10, 11, 2, 5, 3, 8], [7], [1, 2, 3, 4]]


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-370m"])
def test_solo_vs_batched_bit_identical(arch):
    cfg, params, eng = _engine(arch)
    solo = {}
    for i, p in enumerate(PROMPTS):
        out = ServeEngine(cfg, params, max_len=64).generate(
            [Request(i, list(p), max_new_tokens=6)]
        )
        solo.update(out)
    batched = eng.generate(
        [Request(i, list(p), max_new_tokens=6) for i, p in enumerate(PROMPTS)]
    )
    assert batched == solo


def test_continuous_refill_matches_solo():
    """batch_size < n_requests: retired rows are refilled from the pending
    queue (the docstring's promise), and refill leaves outputs solo-exact."""
    cfg, params, eng = _engine(batch_size=2)
    reqs = [
        Request(i, list(p), max_new_tokens=4 + i)
        for i, p in enumerate(PROMPTS)
    ]
    batched = eng.generate(reqs)
    assert all(r.done for r in reqs)
    for i, p in enumerate(PROMPTS):
        out = ServeEngine(cfg, params, max_len=64).generate(
            [Request(i, list(p), max_new_tokens=4 + i)]
        )
        assert batched[i] == out[i]


def test_over_budget_raises_by_default():
    _, _, eng = _engine()
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.generate([Request(0, [1] * 60, max_new_tokens=10)])
    with pytest.raises(ValueError, match="no room to generate"):
        eng.generate([Request(0, [1] * 64, max_new_tokens=1)])
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate([Request(0, [], max_new_tokens=1)])
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.generate([Request(0, [1], max_new_tokens=0)])


def test_overflow_truncate_marks_request():
    _, _, eng = _engine(overflow="truncate")
    r = Request(0, [1] * 60, max_new_tokens=10)
    out = eng.generate([r])
    assert r.truncated and r.done
    assert len(out[0]) == 4  # 64 - 60: capped, not silently short


def test_eos_terminates_and_is_excluded():
    cfg, params, _ = _engine()
    base = ServeEngine(cfg, params, max_len=64).generate(
        [Request(0, [5, 6, 7], max_new_tokens=8)]
    )[0]
    assert len(base) == 8
    eos = base[3]
    cut = base.index(eos)  # first greedy occurrence
    r = Request(0, [5, 6, 7], max_new_tokens=8)
    out = ServeEngine(cfg, params, max_len=64, eos_id=eos).generate([r])
    assert out[0] == base[:cut]  # EOS consumed, never returned
    assert r.done


def test_sliding_window_config_rejected():
    cfg = reduced_config("h2o-danube-3-4b", sliding_window=16)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="sliding_window"):
        ServeEngine(cfg, params, max_len=64)
