"""Pallas SSD chunked-scan kernel vs sequential-recurrence oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.mamba import ssd_chunked


def _mk(B, S, H, P, N, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    return x, dt, A, Bm, Cm


SWEEP = [
    # (B, S, H, P, N, chunk)
    (1, 128, 2, 64, 128, 128),
    (2, 256, 4, 64, 128, 128),
    (1, 256, 2, 32, 64, 64),
    (2, 96, 2, 64, 128, 32),   # S not a multiple of 128 (pad path)
    (1, 200, 3, 16, 32, 64),   # odd everything
]


@pytest.mark.parametrize("shape", SWEEP)
def test_ssd_kernel_matches_sequential(shape):
    B, S, H, P, N, chunk = shape
    x, dt, A, Bm, Cm = _mk(B, S, H, P, N)
    y, state = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, state_ref = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4
    )
    # padded tail contributes dt=0 no-ops, so states agree too
    np.testing.assert_allclose(
        np.asarray(state), np.asarray(state_ref), atol=2e-4, rtol=2e-4
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    x, dt, A, Bm, Cm = _mk(1, 128, 2, 64, 64, dtype=dtype)
    y, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    y_ref, _ = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_model_chunked_path_matches_sequential():
    """The model's pure-jnp chunked SSD (dry-run path) is also validated."""
    x, dt, A, Bm, Cm = _mk(2, 128, 4, 32, 64)
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y2, s2 = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4, rtol=2e-4)


def test_state_enables_continuation():
    """final_state after S1 tokens == init_state for the next S2 tokens."""
    x, dt, A, Bm, Cm = _mk(1, 256, 2, 32, 64)
    y_full, s_full = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    _, s_half = ops.ssd_scan(
        x[:, :128], dt[:, :128], A, Bm[:, :128], Cm[:, :128], chunk=64
    )
    y2, s2 = ref.ssd_scan_ref(
        x[:, 128:], dt[:, 128:], A, Bm[:, 128:], Cm[:, 128:],
        init_state=s_half,
    )
    np.testing.assert_allclose(
        np.asarray(y2), np.asarray(y_full[:, 128:]), atol=2e-4, rtol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(s2), np.asarray(s_full), atol=2e-4, rtol=2e-4
    )
