"""Synthetic MLaaS-like trace generator statistics."""
import numpy as np
import pytest

pytestmark = pytest.mark.sched

from repro.core import TraceConfig, generate_trace, trace_stats


def test_matches_published_statistics():
    cfg = TraceConfig(n_jobs=4000, seed=0)
    jobs = generate_trace(cfg)
    stats = trace_stats(jobs)
    # MLaaS [6]: ~65% of jobs recur >= 5 times; > 70% single-GPU
    assert stats["frac_recurrent_ge5"] >= 0.60
    assert abs(stats["frac_single_gpu"] - cfg.single_gpu_frac) < 0.1
    assert stats["n_jobs"] == pytest.approx(4000, abs=5)


def test_sorted_arrivals_and_ids():
    jobs = generate_trace(TraceConfig(n_jobs=500, seed=1))
    arr = [j.arrival for j in jobs]
    assert arr == sorted(arr)
    assert len({j.job_id for j in jobs}) == len(jobs)
    assert all(0 <= j.arrival <= TraceConfig().horizon for j in jobs)


def test_deterministic():
    a = generate_trace(TraceConfig(n_jobs=300, seed=9))
    b = generate_trace(TraceConfig(n_jobs=300, seed=9))
    assert [(j.arrival, j.n_iters, j.g) for j in a] == [
        (j.arrival, j.n_iters, j.g) for j in b
    ]


def test_max_gpus_clamp():
    jobs = generate_trace(
        TraceConfig(n_jobs=800, seed=2, max_gpus_per_job=8)
    )
    assert max(j.g for j in jobs) <= 8


def test_recurrent_group_iters_similar():
    """Recurring jobs in a group have correlated iteration counts —
    the property that makes prediction possible at all."""
    jobs = generate_trace(TraceConfig(n_jobs=2000, seed=3))
    from collections import defaultdict

    groups = defaultdict(list)
    for j in jobs:
        groups[j.group_id].append(j.n_iters)
    big = [v for v in groups.values() if len(v) >= 8]
    assert big
    # within-group median absolute deviation is small vs global spread
    within = np.mean([np.std(v) / (np.mean(v) + 1e-9) for v in big])
    assert within < 0.6
