"""Random-forest iteration predictor (from scratch) + simpler baselines."""
import numpy as np
import pytest

pytestmark = pytest.mark.sched
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core.predictor import (
    GroupStatPredictor,
    PerfectPredictor,
    RandomForestPredictor,
    RandomForestRegressor,
)
from conftest import make_simple_job


class TestRandomForestRegressor:
    def test_fits_piecewise_constant(self):
        rng = np.random.default_rng(0)
        X = rng.integers(0, 50, size=(2000, 2)).astype(float)
        y = (X[:, 0] * 13 + X[:, 1] * 3) % 97.0
        rf = RandomForestRegressor(n_estimators=30, max_depth=14, seed=0)
        rf.fit(X, y)
        pred = rf.predict(X)
        mae = np.abs(pred - y).mean()
        assert mae < np.abs(y - y.mean()).mean() * 0.5

    def test_deterministic_given_seed(self):
        rng = np.random.default_rng(1)
        X = rng.normal(size=(500, 3))
        y = X[:, 0] * 2 + np.sin(X[:, 1])
        p1 = RandomForestRegressor(n_estimators=10, seed=7).fit(X, y).predict(X)
        p2 = RandomForestRegressor(n_estimators=10, seed=7).fit(X, y).predict(X)
        np.testing.assert_array_equal(p1, p2)

    def test_reduces_variance_vs_single_tree(self):
        rng = np.random.default_rng(2)
        X = rng.normal(size=(800, 2))
        y = X[:, 0] ** 2 + rng.normal(scale=0.3, size=800)
        Xt = rng.normal(size=(200, 2))
        yt = Xt[:, 0] ** 2
        single = RandomForestRegressor(n_estimators=1, seed=0).fit(X, y)
        forest = RandomForestRegressor(n_estimators=50, seed=0).fit(X, y)
        err1 = np.mean((single.predict(Xt) - yt) ** 2)
        err50 = np.mean((forest.predict(Xt) - yt) ** 2)
        assert err50 <= err1

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 200))
    def test_predict_shape(self, n):
        rng = np.random.default_rng(n)
        X = rng.normal(size=(max(n, 40), 2))
        y = rng.normal(size=max(n, 40))
        rf = RandomForestRegressor(n_estimators=3, seed=0).fit(X, y)
        assert rf.predict(X[:n]).shape == (n,)


def _observe_group(pred, gid, iters):
    for i, n in enumerate(iters):
        job = make_simple_job(job_id=i, group_id=gid, n_iters=n)
        pred.observe(job, n)


class TestIterationPredictors:
    def test_unseen_predicts_zero(self):
        for p in (
            GroupStatPredictor("mean"),
            GroupStatPredictor("median"),
            RandomForestPredictor(),
        ):
            job = make_simple_job(group_id=42)
            assert p.predict(job) == 0.0

    def test_group_stats(self):
        p = GroupStatPredictor("mean")
        _observe_group(p, 5, [100, 200, 300])
        assert p.predict(make_simple_job(group_id=5)) == pytest.approx(200)
        p2 = GroupStatPredictor("median")
        _observe_group(p2, 5, [100, 110, 500])
        assert p2.predict(make_simple_job(group_id=5)) == pytest.approx(110)

    def test_perfect(self):
        p = PerfectPredictor()
        assert p.predict(make_simple_job(n_iters=123)) == 123

    def test_rf_max_history_bounds_training_window(self):
        p = RandomForestPredictor(retrain_every=10**9, max_history=100, seed=0)
        for i in range(1000):
            job = make_simple_job(job_id=i, group_id=i % 7, n_iters=50 + i)
            p.observe(job, 50 + i)
        # amortized trim: the buffer never exceeds twice the window
        assert len(p._y) <= 2 * p.max_history
        assert len(p._X) == len(p._y)
        # the retained suffix is the most recent observations
        assert p._y[-1] == 1049.0

    def test_rf_prefit_falls_back_to_group_median(self):
        p = RandomForestPredictor(retrain_every=10**9, seed=0)
        _observe_group(p, 3, [100, 120, 5000])
        assert not p._fitted
        assert p.predict(make_simple_job(group_id=3)) == pytest.approx(120)
        # other groups still unseen -> 0
        assert p.predict(make_simple_job(group_id=4)) == 0.0

    def test_rf_warm_start(self):
        p = RandomForestPredictor(retrain_every=10**9, seed=0)
        _observe_group(p, 1, [200] * 10)
        p.warm_start()  # <32 observations: stays a no-op
        assert not p._fitted
        for g in range(2, 6):
            _observe_group(p, g, [100 * g] * 10)
        p.warm_start()
        assert p._fitted
        assert p._since_retrain == 0
        got = p.predict(make_simple_job(group_id=2, n_iters=200))
        assert 50 <= got <= 500  # a trained forest, not the 0.0 cold path

    def test_rf_predictor_learns_groups(self):
        rng = np.random.default_rng(0)
        p = RandomForestPredictor(retrain_every=64, seed=0)
        group_means = {g: float(rng.integers(50, 500)) for g in range(20)}
        # stream of observations
        for i in range(600):
            g = int(rng.integers(0, 20))
            n = max(1, int(group_means[g] * rng.uniform(0.9, 1.1)))
            job = make_simple_job(job_id=i, group_id=g, n_iters=n)
            p.predict(job)
            p.observe(job, n)
        errs, mean_errs = [], []
        mean_pred = GroupStatPredictor("mean")
        for g, mu in group_means.items():
            job = make_simple_job(group_id=g, n_iters=int(mu))
            errs.append(abs(p.predict(job) - mu))
        assert np.mean(errs) < 0.2 * np.mean(list(group_means.values()))
