"""Degradation-aware scheduling: stragglers, re-timing, and migration.

The two anchor properties (ISSUE 4):

* a straggler run whose events all carry ``speed_factor == 1.0`` — even
  with a migration-capable policy whose penalty is infinite — is
  *bit-identical* to the clean run;
* a ``speed_factor == 0.0`` event reproduces the PR-2 fault path exactly
  (``faults=[(t, m)]`` and ``degradations=[(t, m, 0.0)]`` are the same
  event).

Plus: the cached array-native engine stays bit-identical to the uncached
pure-Python reference engine *under* degradation and migration, re-timing
math is exact, migration strictly helps when idle healthy capacity
exists, and placement avoids degraded capacity via the effective-
bandwidth tiebreak.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.sched

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    ServerClass,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
    straggler_events,
)
from repro.core import timing
from repro.core.cluster import ClusterState
from repro.core.heavy_edge import PlacementCache, map_job_canonical

from conftest import make_simple_job

INF = float("inf")


def assert_identical(ra, rb):
    assert set(ra.records) == set(rb.records)
    for jid, a in ra.records.items():
        b = rb.records[jid]
        assert a.start == b.start, jid
        assert a.completion == b.completion, jid
        assert a.alpha == b.alpha, jid
        assert a.servers == b.servers, jid
        assert a.migrations == b.migrations, jid


def _hom_cluster(n=6):
    return ClusterSpec(
        num_servers=n, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )


def _het_cluster():
    return ClusterSpec.heterogeneous(
        [
            ServerClass(count=3, gpus_per_server=8, b_inter=12.5e9, name="a"),
            ServerClass(count=3, gpus_per_server=8, b_inter=1.25e9, name="b"),
            ServerClass(
                count=3, gpus_per_server=4, b_inter=1.25e9, b_intra=50e9,
                name="c",
            ),
        ],
        b_intra=300e9,
    )


def _trace(seed, n_jobs=120, horizon=1500.0, max_g=16):
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=horizon,
            seed=seed,
            single_gpu_frac=0.4,
            max_gpus_per_job=max_g,
        )
    )


def _asrpt(**kw):
    return ASRPTPolicy(make_predictor("mean"), tau=2.0, **kw)


# ---------------------------------------------------------------------------
# anchor property 1: all-1.0 events are invisible
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_unit_speed_events_bit_identical_to_clean(seed):
    cluster = _hom_cluster()
    jobs = _trace(seed)
    events = straggler_events(
        cluster.num_servers, 1500.0, n_stragglers=3, seed=seed,
        factor_low=1.0, factor_high=1.0,
    )
    assert all(f == 1.0 for _t, _m, f in events)
    clean = simulate(jobs, cluster, _asrpt())
    noop = simulate(
        jobs, cluster, _asrpt(migrate=True, migration_penalty=INF),
        degradations=events,
    )
    assert_identical(clean, noop)
    assert noop.n_migrations == 0


def test_unit_speed_events_bit_identical_hetero_and_baselines():
    cluster = _het_cluster()
    jobs = _trace(5, max_g=24)
    events = [(100.0, 1, 1.0), (400.0, 7, 1.0), (401.0, 1, 1.0)]
    clean = simulate(
        jobs, cluster, _asrpt(refine_mapping=True)
    )
    noop = simulate(
        jobs, cluster,
        _asrpt(refine_mapping=True, migrate=True, migration_penalty=INF),
        degradations=events,
    )
    assert_identical(clean, noop)
    for name in ("SPJF", "WCS-SubTime"):
        pa = BASELINES[name](make_predictor("mean"))
        pb = BASELINES[name](
            make_predictor("mean"), migrate=True, migration_penalty=INF
        )
        assert_identical(
            simulate(jobs, cluster, pa),
            simulate(jobs, cluster, pb, degradations=events),
        )


# ---------------------------------------------------------------------------
# anchor property 2: factor 0.0 == the PR-2 fault path
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_zero_factor_reproduces_fault_path(seed):
    cluster = _hom_cluster()
    jobs = _trace(seed)
    rng = np.random.default_rng(seed)
    server = int(rng.integers(0, cluster.num_servers))
    t_fault = float(rng.uniform(50.0, 1200.0))
    via_fault = simulate(jobs, cluster, _asrpt(), faults=[(t_fault, server)])
    via_deg = simulate(
        jobs, cluster, _asrpt(), degradations=[(t_fault, server, 0.0)]
    )
    assert_identical(via_fault, via_deg)
    # ... and a migration-capable policy changes nothing either: running
    # jobs on a *downed* server are never re-timed or offered (PR-2
    # finish-in-place semantics).
    via_deg_mig = simulate(
        jobs, cluster, _asrpt(migrate=True, migration_penalty=0.0),
        degradations=[(t_fault, server, 0.0)],
    )
    assert_identical(via_fault, via_deg_mig)


# ---------------------------------------------------------------------------
# cached == uncached under degradation + migration
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_cached_equals_uncached_under_degradation(seed):
    cluster = _hom_cluster()
    jobs = _trace(seed, n_jobs=80)
    events = straggler_events(
        cluster.num_servers, 1500.0, n_stragglers=2, seed=seed,
        factor_low=0.25, factor_high=0.75,
    )
    results = []
    for cache in (True, False):
        pol = _asrpt(
            placement_cache=cache, migrate=True, migration_penalty=30.0
        )
        results.append(
            simulate(jobs, cluster, pol, degradations=events)
        )
    assert_identical(*results)


def test_cached_equals_uncached_under_degradation_hetero_refine():
    cluster = _het_cluster()
    jobs = _trace(9, n_jobs=80, max_g=24)
    events = [(200.0, 0, 0.3), (300.0, 4, 0.5), (800.0, 0, 1.0)]
    results = []
    for cache in (True, False):
        pol = _asrpt(
            refine_mapping=True, placement_cache=cache,
            migrate=True, migration_penalty=30.0,
        )
        results.append(simulate(jobs, cluster, pol, degradations=events))
    assert_identical(*results)


# ---------------------------------------------------------------------------
# re-timing math
# ---------------------------------------------------------------------------


def test_single_job_stretch_is_exact():
    """A mid-run slowdown stretches the remaining iterations by 1/f."""
    cluster = ClusterSpec(
        num_servers=1, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )
    job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=1000)
    clean = simulate([job], cluster, _asrpt())
    a0 = clean.records[0].alpha
    t_ev = 37.0
    assert t_ev < clean.records[0].completion
    f = 0.25  # power of two: a0 / f is exact
    deg = simulate(
        [job], cluster, _asrpt(), degradations=[(t_ev, 0, f)]
    )
    rec = deg.records[0]
    iters_rem = 1000.0 - (t_ev - 0.0) / a0
    assert rec.alpha == a0 / f
    assert rec.completion == t_ev + iters_rem * (a0 / f)


def test_recovery_shrinks_completion_again():
    cluster = ClusterSpec(
        num_servers=1, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )
    job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=200)
    clean = simulate([job], cluster, _asrpt())
    slow_only = simulate(
        [job], cluster, _asrpt(), degradations=[(5.0, 0, 0.5)]
    )
    recovered = simulate(
        [job], cluster, _asrpt(),
        degradations=[(5.0, 0, 0.5), (10.0, 0, 1.0)],
    )
    c_clean = clean.records[0].completion
    c_slow = slow_only.records[0].completion
    c_rec = recovered.records[0].completion
    assert c_clean < c_rec < c_slow
    # after recovery the job runs at the clean rate again
    assert recovered.records[0].alpha == clean.records[0].alpha


# ---------------------------------------------------------------------------
# migration behavior
# ---------------------------------------------------------------------------


def _two_server_spec():
    return ClusterSpec(
        num_servers=2, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )


def test_migration_moves_job_off_straggler():
    """One long job on server 0, server 1 idle: a deep slowdown makes the
    checkpoint-restart race an easy win; the record must show the move."""
    cluster = _two_server_spec()
    job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=500)
    stay = simulate(
        [job], cluster, _asrpt(), degradations=[(10.0, 0, 0.1)]
    )
    move = simulate(
        [job], cluster, _asrpt(migrate=True, migration_penalty=5.0),
        degradations=[(10.0, 0, 0.1)],
    )
    assert stay.n_migrations == 0
    assert move.n_migrations == 1
    assert move.records[0].migrations == 1
    assert move.records[0].servers == (1,)
    assert move.records[0].completion < stay.records[0].completion
    # stay keeps the stretched placement on the straggler
    assert stay.records[0].servers == (0,)


def test_migration_respects_infinite_penalty():
    cluster = _two_server_spec()
    job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=500)
    stay = simulate(
        [job], cluster, _asrpt(), degradations=[(10.0, 0, 0.1)]
    )
    never = simulate(
        [job], cluster, _asrpt(migrate=True, migration_penalty=INF),
        degradations=[(10.0, 0, 0.1)],
    )
    assert_identical(stay, never)


def test_migration_waits_for_capacity_freed_later():
    """At the event the cluster is full; a completion then frees healthy
    capacity and the straggler migrates on that later pass."""
    cluster = _two_server_spec()
    # long job fills server 0, short job fills server 1
    long_job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=2000)
    short_job = make_simple_job(job_id=1, replicas=(2, 2), n_iters=50)
    pol = _asrpt(migrate=True, migration_penalty=1.0)
    res = simulate(
        [long_job, short_job], cluster, pol,
        degradations=[(1.0, 0, 0.1)],
    )
    # server 0 degraded at t=1 while both servers are busy; job 1 (on
    # server 1) completes, then job 0 migrates onto the freed server 1
    assert res.n_migrations == 1
    assert res.records[0].servers == (1,)
    assert res.records[0].completion > res.records[1].completion


def test_migration_penalty_charged():
    """The restart penalty is visible in the migrated completion time."""
    cluster = _two_server_spec()
    job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=500)
    t_ev, f = 10.0, 0.125
    base = simulate(
        [job], cluster, _asrpt(migrate=True, migration_penalty=0.0),
        degradations=[(t_ev, 0, f)],
    )
    pen = simulate(
        [job], cluster, _asrpt(migrate=True, migration_penalty=7.0),
        degradations=[(t_ev, 0, f)],
    )
    assert base.n_migrations == pen.n_migrations == 1
    assert pen.records[0].completion == base.records[0].completion + 7.0


def test_migration_improves_flow_on_straggler_trace():
    """Light load + unrecovered stragglers: migrating A-SRPT strictly
    beats finish-in-place A-SRPT (the benchmark acceptance property at
    test scale — migration's win comes from idle healthy capacity, so
    the load here is deliberately light)."""
    cluster = _hom_cluster(n=8)
    jobs = _trace(3, n_jobs=60, horizon=6000.0)
    events = [(1200.0, m, 0.2) for m in (0, 1, 2)]
    stay = simulate(jobs, cluster, _asrpt(), degradations=events)
    move = simulate(
        jobs, cluster, _asrpt(migrate=True, migration_penalty=30.0),
        degradations=events,
    )
    assert move.n_migrations > 0
    assert move.total_flow_time < stay.total_flow_time


def test_retiming_mid_restart_preserves_penalty():
    """A re-timing event inside a migration's restart window must not
    credit the downtime as progress nor drop the remaining penalty."""
    cluster = _two_server_spec()
    job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=500)
    clean = simulate([job], cluster, _asrpt())
    a0 = clean.records[0].alpha
    pen = 20.0
    res = simulate(
        [job], cluster, _asrpt(migrate=True, migration_penalty=pen),
        # migrate off server 0 at t=10 (restart until t=30), then slow
        # the *new* server mid-restart at t=15
        degradations=[(10.0, 0, 0.1), (15.0, 1, 0.8)],
    )
    assert res.n_migrations == 1
    rec = res.records[0]
    assert rec.servers == (1,)
    iters_rem = 500.0 - 10.0 / a0  # brought to t=10 before the migration
    # computing resumes at t = 10 + pen; the t=15 re-timing happens inside
    # the restart window, so no iterations are credited for [10, 15) and
    # the remaining 15 s of downtime stay owed
    assert rec.alpha == a0 / 0.8
    assert rec.completion == (10.0 + pen) + iters_rem * (a0 / 0.8)


def test_job_started_on_degraded_capacity_can_migrate():
    """A job *placed onto* a straggler (the only capacity left) is as
    migratable as one caught there by the event."""
    from repro.core.baselines import spjf

    cluster = _two_server_spec()
    short = make_simple_job(job_id=0, replicas=(2, 2), n_iters=100,
                            arrival=2.0)
    long_ = make_simple_job(job_id=1, replicas=(2, 2), n_iters=3000,
                            arrival=3.0)
    pol = spjf(
        make_predictor("perfect"), migrate=True, migration_penalty=1.0
    )
    res = simulate(
        [short, long_], cluster, pol, degradations=[(1.0, 0, 0.2)]
    )
    # at t=2 the healthy server 1 wins the effective-bandwidth tiebreak;
    # at t=3 only the straggler is free, so the long job starts there
    # (stretched) — and must migrate to server 1 once the short job ends
    assert res.records[0].servers == (1,)
    assert res.n_migrations == 1
    assert res.records[1].migrations == 1
    assert res.records[1].servers == (1,)


def test_dead_straddler_keeps_last_retimed_alpha():
    """A job spanning a degraded server that later dies is frozen at its
    last re-timed alpha: further events on its other servers must not
    re-evaluate the dead server at full speed."""
    from repro.core.baselines import spjf

    cluster = _two_server_spec()
    job = make_simple_job(job_id=0, replicas=(4, 4), n_iters=2000)
    pol = spjf(make_predictor("perfect"))
    # g=8 spans both 4-GPU servers
    stretched = simulate(
        [job], cluster, pol, degradations=[(10.0, 0, 0.5)]
    )
    pol2 = spjf(make_predictor("perfect"))
    frozen = simulate(
        [job], cluster, pol2,
        degradations=[
            (10.0, 0, 0.5),   # straggler
            (20.0, 0, 0.0),   # dies
            (30.0, 1, 1.0),   # no-op on the healthy half (current speed)
            (40.0, 1, 0.9999),  # real event on the healthy half
        ],
    )
    assert stretched.records[0].servers == (0, 1)
    # the t=40 event must not resurrect server 0 at full speed: alpha
    # stays at (or above) the post-t1 stretched value
    assert frozen.records[0].alpha == stretched.records[0].alpha
    assert frozen.records[0].completion == stretched.records[0].completion


def test_job_on_dead_server_never_migrates():
    """Once a straggler's server dies, its checkpoint state is gone: the
    job leaves the migration watchlist and finishes in place even when
    healthy capacity frees up later."""
    from repro.core.baselines import spjf

    cluster = _two_server_spec()
    long_job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=2000)
    short_job = make_simple_job(job_id=1, replicas=(2, 2), n_iters=250)
    pol = spjf(
        make_predictor("perfect"), migrate=True, migration_penalty=1.0
    )
    # SPJF starts the short job first (server 0), long job lands on
    # server 1; server 1 slows at t=10 (long job joins the watch), dies
    # at t=20 (watch purged); the short job's completion then frees
    # server 0 — the dead-server job must NOT checkpoint-restart onto it
    res = simulate(
        [long_job, short_job], cluster, pol,
        degradations=[(10.0, 1, 0.5), (20.0, 1, 0.0)],
    )
    assert res.records[1].servers == (0,)
    assert res.records[0].servers == (1,)
    assert res.n_migrations == 0
    assert res.records[0].migrations == 0


# ---------------------------------------------------------------------------
# degradation-aware placement
# ---------------------------------------------------------------------------


def test_new_placements_avoid_degraded_server():
    """Equal free capacity: the effective-bandwidth tiebreak steers new
    jobs away from the straggler."""
    cluster = ClusterSpec(
        num_servers=3, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )
    jobs = [
        make_simple_job(job_id=i, replicas=(2, 2), n_iters=20,
                        arrival=100.0 + i)
        for i in range(2)
    ]
    res = simulate(
        jobs, cluster, _asrpt(), degradations=[(1.0, 0, 0.5)]
    )
    # two 4-GPU jobs, three empty 4-GPU servers, server 0 degraded:
    # both jobs must land on the healthy servers
    for rec in res.records.values():
        assert 0 not in rec.servers, rec


def test_degraded_placement_alpha_accounts_for_speed():
    """When the straggler is the only capacity, the start alpha is the
    stretched one (scheduler knows the server is slow)."""
    cluster = ClusterSpec(
        num_servers=1, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )
    job = make_simple_job(job_id=0, replicas=(2, 2), n_iters=10,
                          arrival=50.0)
    clean = simulate([job], cluster, _asrpt())
    deg = simulate(
        [job], cluster, _asrpt(), degradations=[(1.0, 0, 0.5)]
    )
    assert deg.records[0].alpha == clean.records[0].alpha / 0.5


# ---------------------------------------------------------------------------
# PlacementCache speed keying
# ---------------------------------------------------------------------------


def test_pcache_speed_key_isolates_degraded_entries():
    cluster = _hom_cluster(n=4)
    job = make_simple_job(job_id=0, replicas=(2, 2))
    pc = PlacementCache(cluster)
    caps = ((0, 2), (1, 2))
    p_clean, a_clean = pc.map_job(job, caps)
    sp = (0.5, 1.0)
    p_deg, a_deg = pc.map_job(job, caps, speeds=sp)
    assert a_deg > a_clean
    # reference equality for the degraded mapping
    p_ref, a_ref = map_job_canonical(
        job, caps, cluster, reference=True, speeds=sp
    )
    assert a_deg == a_ref
    for m in p_ref:
        np.testing.assert_array_equal(p_deg[m], p_ref[m])
    # the clean entry is untouched by the degraded probe
    p2, a2 = pc.map_job(job, caps)
    assert a2 == a_clean
    # an all-1.0 speeds tuple shares the clean entry (no duplicate work)
    hits_before = pc.hits
    p3, a3 = pc.map_job(job, caps, speeds=(1.0, 1.0))
    assert a3 == a_clean and pc.hits == hits_before + 1


def test_cluster_speed_state_roundtrip():
    cluster = _hom_cluster(n=4)
    cs = ClusterState(cluster)
    assert cs.effective_bw_ranks is None
    assert cs.speeds_for(((0, 4), (1, 4))) is None
    assert cs.set_server_speed(2, 0.5)
    assert not cs.set_server_speed(2, 0.5)  # repeat: no-op, no epoch bump
    assert cs.speed_of(2) == 0.5 and cs.has_degraded
    desc, asc = cs.effective_bw_ranks
    assert desc[2] == cluster.num_servers - 1  # slowest sorts last
    assert cs.set_server_speed(2, 1.0)
    assert not cs.has_degraded and cs.effective_bw_ranks is None
    with pytest.raises(ValueError):
        cs.set_server_speed(99, 0.5)
    with pytest.raises(ValueError):
        cs.set_server_speed(0, -0.1)


def test_alpha_speeds_reference_equals_array():
    """timing.alpha(speeds=...) matches alpha_reference(speeds=...) on a
    placement large enough to take the vectorized path."""
    cluster = _hom_cluster(n=6)
    job = make_simple_job(replicas=(8, 8, 8), h_mb=256)
    caps = [(m, 4) for m in range(6)]
    placement, _ = map_job_canonical(job, caps, cluster)
    speeds = {0: 0.3, 3: 0.7}
    a_arr = timing.alpha(job, placement, cluster, speeds=speeds)
    a_ref = timing.alpha_reference(job, placement, cluster, speeds=speeds)
    assert a_arr == a_ref
    assert a_arr > timing.alpha(job, placement, cluster)


# ---------------------------------------------------------------------------
# queue-aware migration race guard (ISSUE 5 satellite; ROADMAP open item)
# ---------------------------------------------------------------------------


def test_migration_queue_guard_deep_queue():
    """The deep-queue case where the PR-4 greedy race migrates and loses.

    One long job straddles a straggler; a queue of short jobs arrives at
    the degradation instant.  Greedy moves the long job onto the only
    free server — every short job then waits out its full occupancy.
    The queue-aware guard charges the claim against the queue head
    (shorter predicted duration than the migrant's penalty + remaining
    time) and skips; the shorts run immediately and the long job still
    migrates once the queue drains.  Net: guarded flow strictly lower.
    """
    cluster = _hom_cluster(n=2)
    long_job = make_simple_job(job_id=0, replicas=(4,), p=1.0, n_iters=200)
    shorts = [
        make_simple_job(job_id=1 + i, replicas=(4,), p=1.0, n_iters=5,
                        arrival=10.0)
        for i in range(6)
    ]
    jobs = [long_job] + shorts
    events = [(10.0, 0, 0.5)]  # the long job's server slows at t=10

    def spjf(guard):
        return BASELINES["SPJF"](
            make_predictor("perfect"), migrate=True, migration_penalty=20.0,
            migration_queue_guard=guard,
        )

    greedy = simulate(jobs, cluster, spjf(False), degradations=events)
    guarded = simulate(jobs, cluster, spjf(True), degradations=events)
    # greedy migrates at t=10 (queue full), claiming the free server
    assert greedy.records[0].migrations == 1
    assert greedy.records[0].start == 0.0
    # the guard defers: shorts run first, the long job moves afterwards
    assert guarded.records[0].migrations == 1
    first_short_done_guarded = min(
        guarded.records[j.job_id].completion for j in shorts
    )
    first_short_done_greedy = min(
        greedy.records[j.job_id].completion for j in shorts
    )
    assert first_short_done_guarded < first_short_done_greedy
    assert guarded.total_flow_time < greedy.total_flow_time


def test_migration_queue_guard_noop_when_queue_empty():
    """With nothing queued the guard never blocks: schedules match the
    unguarded race bit for bit (a lone job can't compete with itself)."""
    cluster = _hom_cluster(n=2)
    job = make_simple_job(job_id=0, replicas=(4,), p=1.0, n_iters=200)
    events = [(10.0, 0, 0.25)]
    base = simulate(
        [job], cluster, _asrpt(migrate=True, migration_penalty=20.0),
        degradations=events,
    )
    guarded = simulate(
        [job], cluster,
        _asrpt(migrate=True, migration_penalty=20.0,
               migration_queue_guard=True),
        degradations=events,
    )
    # the guard is invisible on an empty queue (and this exercises
    # migration_queue_head's vm drain on the A-SRPT side)
    assert base.records[0].migrations == 1
    assert_identical(base, guarded)


# ---------------------------------------------------------------------------
# Degradation-aware admission (ISSUE 6): AlphaCache.bounds(job, cluster)
# ---------------------------------------------------------------------------

from repro.core.asrpt import COMM_HEAVY_DEFAULT
from repro.core.simulator import AlphaCache


def _borderline_job(**kw):
    """Comm-light on a clean homogeneous cluster: a_max/a_min ~ 1.18,
    comfortably below the COMM_HEAVY threshold of 1.5 but close enough
    that a heavy slowdown (compute stretches, comm doesn't) flips it."""
    kw.setdefault("replicas", (2, 2))
    kw.setdefault("p", 0.3)
    kw.setdefault("act_mb", 4.0)
    kw.setdefault("h_mb", 8.0)
    return make_simple_job(**kw)


def test_degraded_bounds_flip_borderline_classification():
    spec = _hom_cluster()
    job = _borderline_job()
    cache = AlphaCache(spec)

    a_max, a_min = cache.bounds(job)
    assert a_max / a_min < COMM_HEAVY_DEFAULT  # comm-light when clean

    cluster = ClusterState(spec)
    cluster.set_server_speed(0, 0.2)  # one straggler at 20% speed
    d_max, d_min = cache.bounds(job, cluster)
    # a clean server still exists, so the optimistic bound is untouched...
    assert d_min == a_min
    # ...but the pessimistic bound stretches by 1/0.2 on the straggler
    assert d_max > a_max
    assert d_max / d_min >= COMM_HEAVY_DEFAULT  # now comm-heavy


def test_degraded_bounds_clean_cluster_is_identity():
    spec = _hom_cluster()
    job = _borderline_job()
    cache = AlphaCache(spec)
    clean = cache.bounds(job)
    cluster = ClusterState(spec)
    assert cache.bounds(job, cluster) == clean
    assert cache.bounds(job, None) == clean


def test_degraded_bounds_all_unit_factors_match_clean():
    """Explicit speed_factor == 1.0 entries are not degradation."""
    spec = _hom_cluster()
    job = _borderline_job()
    cache = AlphaCache(spec)
    clean = cache.bounds(job)
    cluster = ClusterState(spec)
    for m in range(spec.num_servers):
        cluster.set_server_speed(m, 1.0)
    assert cache.bounds(job, cluster) == clean


def test_degraded_bounds_ignore_down_and_draining_servers():
    """A dead or draining straggler can't host new work, so it must not
    poison the admission bounds."""
    spec = _hom_cluster()
    job = _borderline_job()
    cache = AlphaCache(spec)
    clean = cache.bounds(job)

    cluster = ClusterState(spec)
    cluster.set_server_speed(0, 0.2)
    assert cache.bounds(job, cluster) != clean
    cluster.mark_server_down(0)  # killing it clears its speed factor
    assert cache.bounds(job, cluster) == clean

    cluster2 = ClusterState(spec)
    cluster2.set_server_speed(1, 0.2)
    cluster2.drain_server(1)  # draining keeps the factor but blocks entry
    assert cache.bounds(job, cluster2) == clean


def test_degraded_bounds_all_degraded_shift_amin():
    """When every allocatable server is slow, even the optimistic bound
    moves: a_min divides by the best surviving factor."""
    spec = _hom_cluster()
    job = _borderline_job()
    cache = AlphaCache(spec)
    a_max, a_min = cache.bounds(job)

    cluster = ClusterState(spec)
    for m in range(spec.num_servers):
        cluster.set_server_speed(m, 0.5)
    d_max, d_min = cache.bounds(job, cluster)
    assert d_min == pytest.approx(a_min / 0.5)
    assert d_max == pytest.approx(a_max / 0.5)


def test_degraded_bounds_track_recovery():
    """Bounds are memoized per (epoch, speed_version); recovery must be
    observed, not served stale."""
    spec = _hom_cluster()
    job = _borderline_job()
    cache = AlphaCache(spec)
    clean = cache.bounds(job)

    cluster = ClusterState(spec)
    cluster.set_server_speed(0, 0.2)
    degraded = cache.bounds(job, cluster)
    assert degraded != clean
    assert cache.bounds(job, cluster) == degraded  # memo hit
    cluster.set_server_speed(0, 1.0)  # straggler recovers
    assert cache.bounds(job, cluster) == clean


def test_degraded_admission_changes_schedule_only_under_degradation():
    """End to end: ``degraded_admission`` is invisible on a clean cluster
    (bounds fall back to the clean profile) but produces a different
    schedule when every server is heavily slowed — the borderline jobs
    reclassify as comm-heavy and A-SRPT places them differently."""
    spec = _hom_cluster(n=2)
    jobs = [
        make_simple_job(job_id=0, replicas=(2,), n_iters=300, arrival=0.0),
        make_simple_job(job_id=1, replicas=(2,), n_iters=300, arrival=0.0),
        _borderline_job(job_id=2, n_iters=50, arrival=1.0),
    ]
    events = [(0.0, m, 0.2) for m in range(spec.num_servers)]

    def policy(aware):
        return ASRPTPolicy(
            make_predictor("perfect", jobs), degraded_admission=aware,
        )

    clean_naive = simulate(jobs, spec, policy(False))
    clean_aware = simulate(jobs, spec, policy(True))
    assert_identical(clean_naive, clean_aware)

    naive = simulate(jobs, spec, policy(False), degradations=events)
    aware = simulate(jobs, spec, policy(True), degradations=events)
    assert naive.schedule_digest() != aware.schedule_digest()
