"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite must collect (and pass) on a bare environment; property
tests then run as seeded random sampling instead of coverage-guided
search.  Only the API surface the tests actually use is implemented:

    given, settings(max_examples=, deadline=, derandomize=),
    strategies.{integers, floats, booleans, lists, tuples, sampled_from,
    composite}

Each strategy is an object with ``example(rng)``; ``@given`` runs the
test body for ``max_examples`` seeded draws (seed derived from the test
name, so failures reproduce run-to-run).
"""
from __future__ import annotations

import functools
import random
import zlib
from typing import Any, Callable, List, Sequence

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    def __init__(self, fn: Callable[[random.Random], Any]):
        self._fn = fn

    def example(self, rng: random.Random) -> Any:
        return self._fn(rng)

    # hypothesis allows strategy.map(...)
    def map(self, f: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: f(self._fn(rng)))


class _Strategies:
    @staticmethod
    def integers(min_value: int = -(2**31), max_value: int = 2**31) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> Strategy:
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq: Sequence[Any]) -> Strategy:
        items = list(seq)
        return Strategy(lambda rng: items[rng.randrange(len(items))])

    @staticmethod
    def lists(
        elements: Strategy,
        min_size: int = 0,
        max_size: int = 10,
        unique: bool = False,
    ) -> Strategy:
        def draw(rng: random.Random) -> List[Any]:
            n = rng.randint(min_size, max_size)
            out: List[Any] = []
            attempts = 0
            while len(out) < n and attempts < 100 * max(n, 1):
                x = elements.example(rng)
                attempts += 1
                if unique and x in out:
                    continue
                out.append(x)
            return out

        return Strategy(draw)

    @staticmethod
    def tuples(*strategies: Strategy) -> Strategy:
        return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))

    @staticmethod
    def composite(fn: Callable[..., Any]) -> Callable[..., Strategy]:
        @functools.wraps(fn)
        def build(*args: Any, **kwargs: Any) -> Strategy:
            def draw_example(rng: random.Random) -> Any:
                def draw(strategy: Strategy) -> Any:
                    return strategy.example(rng)

                return fn(draw, *args, **kwargs)

            return Strategy(draw_example)

        return build


st = _Strategies()
strategies = st


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Records max_examples on the wrapped test for ``given`` to pick up."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies_pos: Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            # @settings may wrap either this runner (outermost) or fn.
            n = getattr(
                runner,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", DEFAULT_MAX_EXAMPLES),
            )
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strategies_pos)
                fn(*args, *drawn, **kwargs)

        # pytest follows __wrapped__ to the original signature and would
        # treat the drawn parameters as fixtures; hide it so pytest sees
        # the bare (*args, **kwargs) runner instead.
        del runner.__wrapped__
        return runner

    return deco
